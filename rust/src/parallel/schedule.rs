//! OpenMP-style loop schedules (paper §4.1.1) plus the degree-bucketed
//! extension (PR 6).
//!
//! The paper evaluates `static`, `dynamic`, `guided` and `auto` with a
//! chunk size of 2048 and adopts **dynamic** (7% faster than auto on
//! skewed degree distributions).  These are faithful re-implementations
//! of the OpenMP semantics:
//!
//! * `Static`  — chunks assigned round-robin to threads up front;
//! * `Dynamic` — threads grab the next chunk from a shared counter;
//! * `Guided`  — chunk size decays with remaining work
//!   (`max(remaining / (2T), chunk_min)`);
//! * `Auto`    — implementation-defined in OpenMP; here (as in libgomp)
//!   it maps to contiguous static blocks of `n / T`.
//! * `DegreeBucketed` — degree-aware dealing for the Louvain scan
//!   loops: the caller partitions vertex ids once per pass into
//!   low/mid/high-degree buckets ([`ScanOrder`]) and the loop runs over
//!   *positions* of that order through a [`BucketDealer`] — the heavy
//!   tail is drained first, dynamically, with small chunks, so no
//!   worker tail-stalls on a hub vertex; the low-degree bulk is dealt
//!   statically (near-uniform cost, zero dealing contention).  Loops
//!   that carry no degree information (init, scatter, fold) fall back
//!   to `Dynamic` dealing.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::trace;

/// The paper's default chunk size for static/dynamic/guided.
pub const DEFAULT_CHUNK: usize = 2048;

/// Loop schedule kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Schedule {
    Static,
    Dynamic,
    Guided,
    Auto,
    /// Degree-bucketed dealing (PR 6): scan loops run over a
    /// [`ScanOrder`] through a [`BucketDealer`]; degree-blind loops
    /// fall back to `Dynamic`.
    DegreeBucketed,
}

impl Schedule {
    pub fn name(self) -> &'static str {
        match self {
            Schedule::Static => "static",
            Schedule::Dynamic => "dynamic",
            Schedule::Guided => "guided",
            Schedule::Auto => "auto",
            Schedule::DegreeBucketed => "degree-bucketed",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "static" => Some(Schedule::Static),
            "dynamic" => Some(Schedule::Dynamic),
            "guided" => Some(Schedule::Guided),
            "auto" => Some(Schedule::Auto),
            "degree-bucketed" => Some(Schedule::DegreeBucketed),
            _ => None,
        }
    }

    pub const ALL: [Schedule; 5] = [
        Schedule::Static,
        Schedule::Dynamic,
        Schedule::Guided,
        Schedule::Auto,
        Schedule::DegreeBucketed,
    ];
}

/// Shared state handing out chunks of `0..n` to `nthreads` workers.
pub struct ChunkDealer {
    n: usize,
    nthreads: usize,
    chunk: usize,
    schedule: Schedule,
    next: AtomicUsize,
}

impl ChunkDealer {
    pub fn new(n: usize, nthreads: usize, schedule: Schedule, chunk: usize) -> Self {
        Self { n, nthreads: nthreads.max(1), chunk: chunk.max(1), schedule, next: AtomicUsize::new(0) }
    }

    /// Next chunk for worker `tid`, or `None` when the range is drained.
    ///
    /// For `Static`/`Auto` the dealer is deterministic per `tid`; for
    /// `Dynamic`/`Guided` it is first-come-first-served.
    pub fn next_chunk(&self, tid: usize, static_cursor: &mut usize) -> Option<std::ops::Range<usize>> {
        match self.schedule {
            Schedule::Static => {
                // Round-robin chunks: tid gets chunks tid, tid+T, tid+2T, ...
                let idx = *static_cursor;
                let start = (tid + idx * self.nthreads) * self.chunk;
                if start >= self.n {
                    return None;
                }
                *static_cursor += 1;
                Some(start..(start + self.chunk).min(self.n))
            }
            Schedule::Auto => {
                // One contiguous block per thread.
                if *static_cursor > 0 {
                    return None;
                }
                *static_cursor = 1;
                let per = self.n.div_ceil(self.nthreads);
                let start = tid * per;
                if start >= self.n {
                    return None;
                }
                Some(start..(start + per).min(self.n))
            }
            // Degree-blind loops have no ScanOrder to bucket by, so
            // DegreeBucketed degrades to the adopted Dynamic dealing;
            // the scan loops build a `BucketDealer` instead.
            Schedule::Dynamic | Schedule::DegreeBucketed => {
                let start = self.next.fetch_add(self.chunk, Ordering::Relaxed);
                if start >= self.n {
                    return None;
                }
                Some(start..(start + self.chunk).min(self.n))
            }
            Schedule::Guided => {
                // CAS loop: take max(remaining/(2T), chunk_min) from the cursor.
                loop {
                    let start = self.next.load(Ordering::Relaxed);
                    if start >= self.n {
                        return None;
                    }
                    let remaining = self.n - start;
                    let take = (remaining / (2 * self.nthreads)).max(self.chunk).min(remaining);
                    if self
                        .next
                        .compare_exchange_weak(start, start + take, Ordering::AcqRel, Ordering::Relaxed)
                        .is_ok()
                    {
                        return Some(start..start + take);
                    }
                }
            }
        }
    }
}

/// Degree partition of `0..n` driving [`Schedule::DegreeBucketed`].
///
/// `ids` holds every vertex (or community) id exactly once, grouped as
/// `[low | mid | high]` by degree, ascending id within each bucket
/// (stable counting sort, so single-thread runs stay deterministic):
///
/// * low  — degree ≤ `small` (the `SmallTable` fast-path rows);
/// * mid  — `small` < degree ≤ `hub`;
/// * high — degree > `hub` (the heavy tail / hub vertices).
///
/// Scan loops iterate *positions* of `ids` through a [`BucketDealer`];
/// `lo_end` / `mid_end` are the bucket boundaries in position space.
/// The buffer is a reused pass-workspace scratch: `build` never
/// allocates once the first (largest) pass sized it.
#[derive(Debug, Default)]
pub struct ScanOrder {
    pub ids: Vec<u32>,
    pub lo_end: usize,
    pub mid_end: usize,
    /// Parallel-build scratch: per-chunk bucket counts in pass 1,
    /// converted in place to per-chunk per-bucket write offsets for
    /// pass 2 (reused across passes like `ids`).
    chunk_counts: Vec<[usize; 3]>,
}

/// Below this many ids the serial counting sort beats two team jobs.
const PAR_BUILD_MIN: usize = 8192;

impl ScanOrder {
    /// Heap bytes reserved by the order buffers (capacity; PR 8 memory
    /// accounting).
    pub fn reserved_bytes(&self) -> usize {
        self.ids.capacity() * std::mem::size_of::<u32>()
            + self.chunk_counts.capacity() * std::mem::size_of::<[usize; 3]>()
    }
}

impl ScanOrder {
    /// Partition `0..n` by `degree_of` into the reused buffer.
    pub fn build(&mut self, n: usize, small: usize, hub: usize, degree_of: impl Fn(usize) -> usize) {
        let hub = hub.max(small);
        let (mut lo, mut mid) = (0usize, 0usize);
        for v in 0..n {
            let d = degree_of(v);
            if d <= small {
                lo += 1;
            } else if d <= hub {
                mid += 1;
            }
        }
        self.lo_end = lo;
        self.mid_end = lo + mid;
        self.ids.clear();
        self.ids.resize(n, 0);
        let (mut at_lo, mut at_mid, mut at_hi) = (0usize, lo, lo + mid);
        for v in 0..n {
            let d = degree_of(v);
            let slot = if d <= small {
                &mut at_lo
            } else if d <= hub {
                &mut at_mid
            } else {
                &mut at_hi
            };
            self.ids[*slot] = v as u32;
            *slot += 1;
        }
        debug_assert_eq!(at_lo, self.lo_end);
        debug_assert_eq!(at_mid, self.mid_end);
        debug_assert_eq!(at_hi, n);
    }

    /// [`ScanOrder::build`] parallelized on `exec` (PR-7 ROADMAP
    /// follow-on): one team job per counting-sort pass.  Pass 1 counts
    /// bucket sizes per fixed `opts.chunk`-wide id range; a serial
    /// prefix converts the counts to per-chunk per-bucket write
    /// offsets; pass 2 scatters ids to those offsets.  Chunks partition
    /// `0..n` in ascending order and each chunk writes its ids in
    /// ascending order, so the result is bit-identical to the serial
    /// build (stable: ascending id within each bucket — asserted by
    /// `build_exec_matches_serial_build`).  Small or single-threaded
    /// inputs fall back to the serial path; either way the cost is
    /// visible as a `scan_order.build` span when tracing.
    pub fn build_exec(
        &mut self,
        n: usize,
        small: usize,
        hub: usize,
        degree_of: impl Fn(usize) -> usize + Sync,
        opts: super::pool::ParallelOpts,
        exec: super::team::Exec,
    ) {
        let mut sp = trace::span("scan_order.build", trace::Category::Order, [n as u64; 4]);
        let parallel = opts.threads > 1 && n >= PAR_BUILD_MIN;
        if !parallel {
            self.build(n, small, hub, degree_of);
        } else {
            self.build_parallel(n, small, hub, &degree_of, opts, exec);
        }
        if let Some(g) = sp.as_mut() {
            g.args = [n as u64, self.lo_end as u64, self.mid_end as u64, parallel as u64];
        }
    }

    fn build_parallel(
        &mut self,
        n: usize,
        small: usize,
        hub: usize,
        degree_of: &(impl Fn(usize) -> usize + Sync),
        opts: super::pool::ParallelOpts,
        exec: super::team::Exec,
    ) {
        let hub = hub.max(small);
        let chunk = opts.chunk.max(1);
        let nchunks = n.div_ceil(chunk);
        let bucket_of = |v: usize| {
            let d = degree_of(v);
            if d <= small {
                0usize
            } else if d <= hub {
                1
            } else {
                2
            }
        };
        // Both team jobs deal whole chunk-count slots statically: the
        // per-slot work is one `chunk`-wide id scan, near-uniform.
        let job_opts = super::pool::ParallelOpts {
            threads: opts.threads,
            schedule: Schedule::Static,
            chunk: 1,
            record: false,
        };
        self.chunk_counts.clear();
        self.chunk_counts.resize(nchunks, [0; 3]);
        exec.run_disjoint_mut(&mut self.chunk_counts, job_opts, |r, slots| {
            for (k, slot) in r.zip(slots.iter_mut()) {
                let mut cnt = [0usize; 3];
                for v in k * chunk..((k + 1) * chunk).min(n) {
                    cnt[bucket_of(v)] += 1;
                }
                *slot = cnt;
            }
        });
        // Serial prefix over nchunks slots (three adds each): bucket
        // totals, then counts → write offsets in place.
        let mut total = [0usize; 3];
        for c in &self.chunk_counts {
            for b in 0..3 {
                total[b] += c[b];
            }
        }
        self.lo_end = total[0];
        self.mid_end = total[0] + total[1];
        let mut run = [0, self.lo_end, self.mid_end];
        for c in self.chunk_counts.iter_mut() {
            let cnt = *c;
            *c = run;
            for b in 0..3 {
                run[b] += cnt[b];
            }
        }
        debug_assert_eq!(run, [self.lo_end, self.mid_end, n]);
        self.ids.clear();
        self.ids.resize(n, 0);
        let ids = super::pool::RawSend(self.ids.as_mut_ptr());
        let offsets = &self.chunk_counts;
        exec.run(nchunks, job_opts, move |r| {
            let ids = ids;
            for k in r {
                let mut at = offsets[k];
                for v in k * chunk..((k + 1) * chunk).min(n) {
                    let b = bucket_of(v);
                    // SAFETY: the offsets are a prefix sum of disjoint
                    // per-chunk bucket counts, so every slot of
                    // `0..n` is written by exactly one (chunk, id).
                    unsafe { *ids.0.add(at[b]) = v as u32 };
                    at[b] += 1;
                }
            }
        });
    }

    /// The dealing spec for a loop over this order's positions.
    pub fn spec(&self) -> DealSpec {
        DealSpec::Bucketed { lo_end: self.lo_end, mid_end: self.mid_end }
    }
}

/// How a loop's chunks should be dealt — resolved to a [`Dealer`] once
/// the effective thread count is known (the team clamps `opts.threads`
/// to its width, so the spec travels and the dealer is built late).
#[derive(Clone, Copy, Debug)]
pub enum DealSpec {
    /// One [`ChunkDealer`] over `0..n` per `opts.schedule`.
    Flat,
    /// A [`BucketDealer`] over the positions of a [`ScanOrder`] with
    /// these bucket boundaries.
    Bucketed { lo_end: usize, mid_end: usize },
}

impl DealSpec {
    pub fn build(self, n: usize, nthreads: usize, schedule: Schedule, chunk: usize) -> Dealer {
        match self {
            DealSpec::Flat => Dealer::Flat(ChunkDealer::new(n, nthreads, schedule, chunk)),
            DealSpec::Bucketed { lo_end, mid_end } => {
                Dealer::Bucketed(BucketDealer::new(n, lo_end, mid_end, nthreads, chunk))
            }
        }
    }
}

/// Hub chunks are `chunk / HUB_CHUNK_DIV` (min 1): a degree-200k hub
/// must not ride in a 2048-wide chunk next to 2047 leaves.
const HUB_CHUNK_DIV: usize = 32;

/// Three-legged dealer over the positions of a [`ScanOrder`]:
///
/// * leg 0 — high bucket (`mid_end..n`), `Dynamic`, small chunks: the
///   expensive rows go first and are balanced finely;
/// * leg 1 — mid bucket (`lo_end..mid_end`), `Dynamic`, full chunks;
/// * leg 2 — low bucket (`0..lo_end`), `Static`, full chunks: the
///   near-uniform bulk needs no dealing contention at all.
///
/// Legs drain in that order; together they hand out every position of
/// `0..n` exactly once (the same disjoint-cover contract as
/// [`ChunkDealer`], asserted by the schedule tests).
pub struct BucketDealer {
    legs: [ChunkDealer; 3],
    offsets: [usize; 3],
}

impl BucketDealer {
    pub fn new(n: usize, lo_end: usize, mid_end: usize, nthreads: usize, chunk: usize) -> Self {
        let lo_end = lo_end.min(n);
        let mid_end = mid_end.clamp(lo_end, n);
        let hub_chunk = (chunk / HUB_CHUNK_DIV).max(1);
        Self {
            legs: [
                ChunkDealer::new(n - mid_end, nthreads, Schedule::Dynamic, hub_chunk),
                ChunkDealer::new(mid_end - lo_end, nthreads, Schedule::Dynamic, chunk),
                ChunkDealer::new(lo_end, nthreads, Schedule::Static, chunk),
            ],
            offsets: [mid_end, lo_end, 0],
        }
    }

    /// Next chunk of positions for worker `tid`, or `None` when all
    /// three legs are drained.
    pub fn next_chunk(&self, tid: usize, cursor: &mut DealCursor) -> Option<std::ops::Range<usize>> {
        while cursor.leg < self.legs.len() {
            if let Some(r) = self.legs[cursor.leg].next_chunk(tid, &mut cursor.static_cursor) {
                let off = self.offsets[cursor.leg];
                return Some(r.start + off..r.end + off);
            }
            cursor.leg += 1;
            cursor.static_cursor = 0;
        }
        None
    }
}

/// Per-worker dealing cursor shared by both dealer kinds (`leg` is
/// unused by the flat dealer).
#[derive(Default)]
pub struct DealCursor {
    pub leg: usize,
    pub static_cursor: usize,
}

/// A resolved chunk dealer: flat (one schedule over `0..n`) or
/// degree-bucketed (three legs over scan-order positions).
pub enum Dealer {
    Flat(ChunkDealer),
    Bucketed(BucketDealer),
}

impl Dealer {
    #[inline]
    pub fn next_chunk(&self, tid: usize, cursor: &mut DealCursor) -> Option<std::ops::Range<usize>> {
        match self {
            Dealer::Flat(d) => d.next_chunk(tid, &mut cursor.static_cursor),
            Dealer::Bucketed(d) => d.next_chunk(tid, cursor),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(n: usize, t: usize, s: Schedule, chunk: usize) -> Vec<std::ops::Range<usize>> {
        let dealer = ChunkDealer::new(n, t, s, chunk);
        let mut out = Vec::new();
        // Emulate t workers taking turns (single-threaded drain covers all
        // schedules deterministically for Static/Auto; Dynamic/Guided
        // correctness here = full disjoint cover).
        let mut cursors = vec![0usize; t];
        let mut live: Vec<usize> = (0..t).collect();
        while !live.is_empty() {
            live.retain(|&tid| {
                if let Some(r) = dealer.next_chunk(tid, &mut cursors[tid]) {
                    out.push(r);
                    true
                } else {
                    false
                }
            });
        }
        out
    }

    fn assert_cover(n: usize, chunks: &[std::ops::Range<usize>]) {
        let mut seen = vec![false; n];
        for r in chunks {
            for i in r.clone() {
                assert!(!seen[i], "index {i} covered twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "range not fully covered");
    }

    #[test]
    fn all_schedules_cover_disjointly() {
        for s in Schedule::ALL {
            for (n, t, c) in [(100, 4, 8), (1, 1, 2048), (2048, 3, 100), (10_000, 8, 64)] {
                let chunks = drain(n, t, s, c);
                assert_cover(n, &chunks);
            }
        }
    }

    #[test]
    fn static_round_robin_layout() {
        let chunks = drain(40, 2, Schedule::Static, 10);
        // tid0: [0,10) [20,30); tid1: [10,20) [30,40)
        assert!(chunks.contains(&(0..10)));
        assert!(chunks.contains(&(20..30)));
    }

    #[test]
    fn auto_is_contiguous_blocks() {
        let chunks = drain(100, 4, Schedule::Auto, 2048);
        assert_eq!(chunks.len(), 4);
        assert!(chunks.iter().any(|r| *r == (0..25)));
        assert!(chunks.iter().any(|r| *r == (75..100)));
    }

    #[test]
    fn guided_chunks_decay() {
        let chunks = drain(100_000, 4, Schedule::Guided, 64);
        assert!(chunks[0].len() > chunks[chunks.len() - 1].len());
        assert!(chunks.last().unwrap().len() >= 1);
    }

    #[test]
    fn empty_range_yields_nothing() {
        for s in Schedule::ALL {
            assert!(drain(0, 4, s, 16).is_empty());
        }
    }

    #[test]
    fn parse_round_trips() {
        for s in Schedule::ALL {
            assert_eq!(Schedule::parse(s.name()), Some(s));
        }
        assert_eq!(Schedule::parse("bogus"), None);
    }

    fn drain_bucketed(
        n: usize,
        lo_end: usize,
        mid_end: usize,
        t: usize,
        chunk: usize,
    ) -> Vec<std::ops::Range<usize>> {
        let dealer = BucketDealer::new(n, lo_end, mid_end, t, chunk);
        let mut out = Vec::new();
        let mut cursors: Vec<DealCursor> = (0..t).map(|_| DealCursor::default()).collect();
        let mut live: Vec<usize> = (0..t).collect();
        while !live.is_empty() {
            live.retain(|&tid| {
                if let Some(r) = dealer.next_chunk(tid, &mut cursors[tid]) {
                    out.push(r);
                    true
                } else {
                    false
                }
            });
        }
        out
    }

    #[test]
    fn bucket_dealer_covers_disjointly() {
        // (n, lo_end, mid_end) shapes: mixed, all-low, all-high,
        // all-mid, empty buckets at both ends, tiny and chunk-straddling.
        for (n, lo, mid) in [
            (10_000, 7_000, 9_500),
            (513, 513, 513),
            (513, 0, 0),
            (513, 0, 513),
            (1, 0, 0),
            (1, 1, 1),
            (4096, 100, 4000),
            (100, 33, 66),
        ] {
            for t in [1, 3, 8] {
                for c in [1, 16, 2048] {
                    let chunks = drain_bucketed(n, lo, mid, t, c);
                    assert_cover(n, &chunks);
                }
            }
        }
    }

    #[test]
    fn bucket_dealer_drains_high_bucket_first() {
        // Single worker: every high-bucket position must be dealt
        // before any mid or low one, and mid before low.
        let chunks = drain_bucketed(300, 100, 200, 1, 16);
        let flat: Vec<usize> = chunks.into_iter().flatten().collect();
        let first_mid = flat.iter().position(|&i| (100..200).contains(&i)).unwrap();
        let first_lo = flat.iter().position(|&i| i < 100).unwrap();
        let last_hi = flat.iter().rposition(|&i| i >= 200).unwrap();
        assert!(last_hi < first_mid, "high bucket not drained before mid");
        assert!(first_mid < first_lo, "mid bucket not drained before low");
    }

    #[test]
    fn bucket_dealer_uses_small_hub_chunks() {
        // High leg chunk = (2048/32).max(1) = 64.
        let chunks = drain_bucketed(10_000, 0, 0, 4, 2048);
        assert!(chunks.iter().all(|r| r.len() <= 64));
        assert_cover(10_000, &chunks);
    }

    #[test]
    fn deal_spec_builds_matching_dealer() {
        let flat = DealSpec::Flat.build(100, 2, Schedule::Dynamic, 16);
        assert!(matches!(flat, Dealer::Flat(_)));
        let bucketed =
            DealSpec::Bucketed { lo_end: 10, mid_end: 20 }.build(100, 2, Schedule::DegreeBucketed, 16);
        assert!(matches!(bucketed, Dealer::Bucketed(_)));
        // Unified cursor drain through the Dealer wrapper still covers.
        let mut cur = DealCursor::default();
        let mut seen = vec![false; 100];
        while let Some(r) = bucketed.next_chunk(0, &mut cur) {
            for i in r {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        // Drain tid 1 too: any static-leg chunks round-robined to it
        // must not overlap what tid 0 already took.
        let mut cur1 = DealCursor::default();
        while let Some(r) = bucketed.next_chunk(1, &mut cur1) {
            for i in r {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn scan_order_partitions_by_degree() {
        // degree(v) = v: 0..=4 low, 5..=8 mid, 9.. high with (4, 8).
        let mut order = ScanOrder::default();
        order.build(12, 4, 8, |v| v);
        assert_eq!(order.lo_end, 5);
        assert_eq!(order.mid_end, 9);
        assert_eq!(order.ids[..5], [0, 1, 2, 3, 4]);
        assert_eq!(order.ids[5..9], [5, 6, 7, 8]);
        assert_eq!(order.ids[9..], [9, 10, 11]);
        assert!(matches!(order.spec(), DealSpec::Bucketed { lo_end: 5, mid_end: 9 }));
    }

    #[test]
    fn scan_order_is_stable_and_reusable() {
        let degs = [3usize, 900, 2, 17, 500, 1, 17, 1000, 4];
        let mut order = ScanOrder::default();
        // Build twice into the same buffer — reuse must not leak state.
        for _ in 0..2 {
            order.build(degs.len(), 16, 256, |v| degs[v]);
            // Ascending ids within each bucket (stable counting sort).
            assert_eq!(order.ids[..order.lo_end], [0, 2, 5, 8]);
            assert_eq!(order.ids[order.lo_end..order.mid_end], [3, 6]);
            assert_eq!(order.ids[order.mid_end..], [1, 4, 7]);
        }
        // Shrinking n reuses the allocation and re-derives the bounds.
        order.build(3, 16, 256, |v| degs[v]);
        assert_eq!(order.ids.len(), 3);
        assert_eq!(order.ids[..order.lo_end], [0, 2]);
        assert_eq!(order.ids[order.mid_end..], [1]);
    }

    #[test]
    fn build_exec_matches_serial_build() {
        use crate::parallel::pool::ParallelOpts;
        use crate::parallel::team::{Exec, Team};
        let team = Team::new(4);
        let exec = Exec::team(&team);
        let n = PAR_BUILD_MIN + 1234; // force the parallel path
        let deg = |v: usize| (v * 7919) % 600; // pseudo-random, all buckets
        for (small, hub) in [(16, 256), (0, 256), (10, 2), (600, 600)] {
            let mut serial = ScanOrder::default();
            serial.build(n, small, hub, deg);
            let mut par = ScanOrder::default();
            let opts = ParallelOpts {
                threads: 4,
                schedule: Schedule::Dynamic,
                chunk: 512,
                record: false,
            };
            // Build twice into the same buffer — scratch reuse must not
            // leak state between passes.
            for _ in 0..2 {
                par.build_exec(n, small, hub, deg, opts, exec);
                assert_eq!(par.lo_end, serial.lo_end);
                assert_eq!(par.mid_end, serial.mid_end);
                assert_eq!(par.ids, serial.ids, "(small, hub) = ({small}, {hub})");
            }
        }
        // Small n falls back to the serial path and still matches.
        let mut serial = ScanOrder::default();
        serial.build(100, 16, 256, deg);
        let mut par = ScanOrder::default();
        let opts = ParallelOpts {
            threads: 4,
            schedule: Schedule::Dynamic,
            chunk: 512,
            record: false,
        };
        par.build_exec(100, 16, 256, deg, opts, exec);
        assert_eq!(par.ids, serial.ids);
    }

    #[test]
    fn scan_order_degenerate_thresholds() {
        let mut order = ScanOrder::default();
        // hub < small is clamped to small: no mid bucket.
        order.build(6, 10, 2, |v| v);
        assert_eq!(order.lo_end, order.mid_end);
        // All vertices in one bucket still covers everything once.
        order.build(6, 0, 0, |_| 5);
        assert_eq!(order.lo_end, 0);
        assert_eq!(order.mid_end, 0);
        let mut ids: Vec<u32> = order.ids.clone();
        ids.sort_unstable();
        assert_eq!(ids, [0, 1, 2, 3, 4, 5]);
    }
}
