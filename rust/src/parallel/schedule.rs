//! OpenMP-style loop schedules (paper §4.1.1).
//!
//! The paper evaluates `static`, `dynamic`, `guided` and `auto` with a
//! chunk size of 2048 and adopts **dynamic** (7% faster than auto on
//! skewed degree distributions).  These are faithful re-implementations
//! of the OpenMP semantics:
//!
//! * `Static`  — chunks assigned round-robin to threads up front;
//! * `Dynamic` — threads grab the next chunk from a shared counter;
//! * `Guided`  — chunk size decays with remaining work
//!   (`max(remaining / (2T), chunk_min)`);
//! * `Auto`    — implementation-defined in OpenMP; here (as in libgomp)
//!   it maps to contiguous static blocks of `n / T`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// The paper's default chunk size for static/dynamic/guided.
pub const DEFAULT_CHUNK: usize = 2048;

/// Loop schedule kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Schedule {
    Static,
    Dynamic,
    Guided,
    Auto,
}

impl Schedule {
    pub fn name(self) -> &'static str {
        match self {
            Schedule::Static => "static",
            Schedule::Dynamic => "dynamic",
            Schedule::Guided => "guided",
            Schedule::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "static" => Some(Schedule::Static),
            "dynamic" => Some(Schedule::Dynamic),
            "guided" => Some(Schedule::Guided),
            "auto" => Some(Schedule::Auto),
            _ => None,
        }
    }

    pub const ALL: [Schedule; 4] =
        [Schedule::Static, Schedule::Dynamic, Schedule::Guided, Schedule::Auto];
}

/// Shared state handing out chunks of `0..n` to `nthreads` workers.
pub struct ChunkDealer {
    n: usize,
    nthreads: usize,
    chunk: usize,
    schedule: Schedule,
    next: AtomicUsize,
}

impl ChunkDealer {
    pub fn new(n: usize, nthreads: usize, schedule: Schedule, chunk: usize) -> Self {
        Self { n, nthreads: nthreads.max(1), chunk: chunk.max(1), schedule, next: AtomicUsize::new(0) }
    }

    /// Next chunk for worker `tid`, or `None` when the range is drained.
    ///
    /// For `Static`/`Auto` the dealer is deterministic per `tid`; for
    /// `Dynamic`/`Guided` it is first-come-first-served.
    pub fn next_chunk(&self, tid: usize, static_cursor: &mut usize) -> Option<std::ops::Range<usize>> {
        match self.schedule {
            Schedule::Static => {
                // Round-robin chunks: tid gets chunks tid, tid+T, tid+2T, ...
                let idx = *static_cursor;
                let start = (tid + idx * self.nthreads) * self.chunk;
                if start >= self.n {
                    return None;
                }
                *static_cursor += 1;
                Some(start..(start + self.chunk).min(self.n))
            }
            Schedule::Auto => {
                // One contiguous block per thread.
                if *static_cursor > 0 {
                    return None;
                }
                *static_cursor = 1;
                let per = self.n.div_ceil(self.nthreads);
                let start = tid * per;
                if start >= self.n {
                    return None;
                }
                Some(start..(start + per).min(self.n))
            }
            Schedule::Dynamic => {
                let start = self.next.fetch_add(self.chunk, Ordering::Relaxed);
                if start >= self.n {
                    return None;
                }
                Some(start..(start + self.chunk).min(self.n))
            }
            Schedule::Guided => {
                // CAS loop: take max(remaining/(2T), chunk_min) from the cursor.
                loop {
                    let start = self.next.load(Ordering::Relaxed);
                    if start >= self.n {
                        return None;
                    }
                    let remaining = self.n - start;
                    let take = (remaining / (2 * self.nthreads)).max(self.chunk).min(remaining);
                    if self
                        .next
                        .compare_exchange_weak(start, start + take, Ordering::AcqRel, Ordering::Relaxed)
                        .is_ok()
                    {
                        return Some(start..start + take);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(n: usize, t: usize, s: Schedule, chunk: usize) -> Vec<std::ops::Range<usize>> {
        let dealer = ChunkDealer::new(n, t, s, chunk);
        let mut out = Vec::new();
        // Emulate t workers taking turns (single-threaded drain covers all
        // schedules deterministically for Static/Auto; Dynamic/Guided
        // correctness here = full disjoint cover).
        let mut cursors = vec![0usize; t];
        let mut live: Vec<usize> = (0..t).collect();
        while !live.is_empty() {
            live.retain(|&tid| {
                if let Some(r) = dealer.next_chunk(tid, &mut cursors[tid]) {
                    out.push(r);
                    true
                } else {
                    false
                }
            });
        }
        out
    }

    fn assert_cover(n: usize, chunks: &[std::ops::Range<usize>]) {
        let mut seen = vec![false; n];
        for r in chunks {
            for i in r.clone() {
                assert!(!seen[i], "index {i} covered twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "range not fully covered");
    }

    #[test]
    fn all_schedules_cover_disjointly() {
        for s in Schedule::ALL {
            for (n, t, c) in [(100, 4, 8), (1, 1, 2048), (2048, 3, 100), (10_000, 8, 64)] {
                let chunks = drain(n, t, s, c);
                assert_cover(n, &chunks);
            }
        }
    }

    #[test]
    fn static_round_robin_layout() {
        let chunks = drain(40, 2, Schedule::Static, 10);
        // tid0: [0,10) [20,30); tid1: [10,20) [30,40)
        assert!(chunks.contains(&(0..10)));
        assert!(chunks.contains(&(20..30)));
    }

    #[test]
    fn auto_is_contiguous_blocks() {
        let chunks = drain(100, 4, Schedule::Auto, 2048);
        assert_eq!(chunks.len(), 4);
        assert!(chunks.iter().any(|r| *r == (0..25)));
        assert!(chunks.iter().any(|r| *r == (75..100)));
    }

    #[test]
    fn guided_chunks_decay() {
        let chunks = drain(100_000, 4, Schedule::Guided, 64);
        assert!(chunks[0].len() > chunks[chunks.len() - 1].len());
        assert!(chunks.last().unwrap().len() >= 1);
    }

    #[test]
    fn empty_range_yields_nothing() {
        for s in Schedule::ALL {
            assert!(drain(0, 4, s, 16).is_empty());
        }
    }

    #[test]
    fn parse_round_trips() {
        for s in Schedule::ALL {
            assert_eq!(Schedule::parse(s.name()), Some(s));
        }
        assert_eq!(Schedule::parse("bogus"), None);
    }
}
