//! Software prefetch for the membership-gather scan loops (PR 6).
//!
//! The local-moving hot loop walks a vertex's CSR neighbour list and
//! gathers `membership[neighbour]` — a data-dependent random access
//! per edge that the hardware prefetcher cannot predict.  The paper's
//! 560 M-edges/s rate (§3) lives or dies on this gather; issuing an
//! explicit prefetch a fixed distance ahead in the neighbour list hides
//! most of the miss latency on large graphs where the membership array
//! far exceeds LLC.
//!
//! `prefetch_read` is a *hint*: it is bounds-checked, has no observable
//! effect on program semantics, and compiles to a no-op on targets
//! without a prefetch intrinsic (the cfg gate keeps the build portable
//! — only `x86_64` emits `PREFETCHT0` today).  The lookahead distance
//! is a [`LouvainParams`](crate::louvain::LouvainParams) knob
//! (`prefetch_distance`, 0 disables).

/// Hint the CPU to pull `data[index]` into all cache levels.
///
/// Out-of-range indices are ignored, so callers can prefetch blindly
/// past the end of a neighbour list without branching on the tail.
#[inline(always)]
pub fn prefetch_read<T>(data: &[T], index: usize) {
    if index < data.len() {
        #[cfg(target_arch = "x86_64")]
        unsafe {
            // _MM_HINT_T0: fetch into every level; the gathered value
            // is consumed within a few iterations, so temporal locality
            // is the right hint.
            core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(
                data.as_ptr().add(index) as *const i8,
            );
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            // Portable fallback: no-op.  (aarch64 has `prfm` but no
            // stable core::arch intrinsic; the reference to `data`
            // keeps the signature identical across targets.)
            let _ = data;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn in_bounds_and_out_of_bounds_are_safe() {
        let v: Vec<u64> = (0..100).collect();
        for i in 0..200 {
            prefetch_read(&v, i); // must never fault, even past the end
        }
        assert_eq!(v[99], 99);
    }

    #[test]
    fn works_on_atomic_slices() {
        // The scan loops prefetch `&[AtomicU32]` membership words.
        let memb: Vec<AtomicU32> = (0..8).map(AtomicU32::new).collect();
        prefetch_read(&memb, 3);
        prefetch_read(&memb, 8); // one past the end: ignored
        let empty: [AtomicU32; 0] = [];
        prefetch_read(&empty, 0);
    }
}
