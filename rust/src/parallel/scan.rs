//! Prefix sums (exclusive scan), serial and blocked-parallel.
//!
//! The aggregation phase builds both the community-vertices CSR and the
//! holey super-vertex CSR from degree counts via exclusive scan
//! (Algorithm 3, lines 4 & 9).  The parallel version is the standard
//! three-phase blocked scan (local reduce → scan of block sums → local
//! scan with offset), runnable on either the persistent worker
//! [`Team`](super::team::Team) (via [`exclusive_scan_exec`]) or the
//! scoped fork-join pool.

use super::pool::{ParallelOpts, RawSend};
use super::team::Exec;
use crate::parallel::atomics::as_atomic_u64;

/// In-place exclusive scan; returns the grand total.
pub fn exclusive_scan_serial(v: &mut [usize]) -> usize {
    let mut acc = 0usize;
    for x in v.iter_mut() {
        let t = *x;
        *x = acc;
        acc += t;
    }
    acc
}

/// Blocked-parallel in-place exclusive scan on the scoped pool;
/// returns the grand total.  See [`exclusive_scan_exec`] for the
/// team-backed variant used on the Louvain hot path.
pub fn exclusive_scan(v: &mut [usize], threads: usize) -> usize {
    exclusive_scan_exec(v, threads, Exec::scoped())
}

/// Blocked-parallel in-place exclusive scan on `exec`; returns the
/// grand total.  Falls back to serial when the input is small or
/// `threads == 1`.
pub fn exclusive_scan_exec(v: &mut [usize], threads: usize, exec: Exec) -> usize {
    const MIN_PAR: usize = 1 << 14;
    let n = v.len();
    if threads <= 1 || n < MIN_PAR {
        return exclusive_scan_serial(v);
    }
    let nblocks = threads * 4;
    let bsz = n.div_ceil(nblocks);
    let mut block_sums = vec![0u64; nblocks];

    // Phase 1: per-block reduction.
    {
        let sums = as_atomic_u64(&mut block_sums);
        let data = &*v;
        exec.run(nblocks, ParallelOpts { threads, chunk: 1, ..Default::default() }, |r| {
            for b in r {
                let lo = b * bsz;
                if lo >= n {
                    continue;
                }
                let hi = ((b + 1) * bsz).min(n);
                let s: usize = data[lo..hi].iter().sum();
                sums[b].store(s as u64, std::sync::atomic::Ordering::Relaxed);
            }
        });
    }

    // Phase 2: scan block sums (serial; nblocks is tiny).
    let mut acc = 0usize;
    let mut offsets = vec![0usize; nblocks];
    for b in 0..nblocks {
        offsets[b] = acc;
        acc += block_sums[b] as usize;
    }
    let total = acc;

    // Phase 3: local exclusive scan with the block offset.
    {
        let offsets = &offsets;
        // SAFETY of the split: blocks are disjoint ranges of `v`.
        let ptr = RawSend(v.as_mut_ptr());
        exec.run(nblocks, ParallelOpts { threads, chunk: 1, ..Default::default() }, move |r| {
            let ptr = ptr; // capture the whole RawSend (2021 disjoint capture)
            for b in r {
                let lo = b * bsz;
                if lo >= n {
                    continue;
                }
                let hi = ((b + 1) * bsz).min(n);
                let mut acc = offsets[b];
                // SAFETY: [lo, hi) is owned exclusively by block b.
                let slice = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo), hi - lo) };
                for x in slice {
                    let t = *x;
                    *x = acc;
                    acc += t;
                }
            }
        });
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::prng::Xoshiro256;
    use crate::parallel::team::Team;

    #[test]
    fn serial_scan_basic() {
        let mut v = vec![3, 1, 4, 1, 5];
        let total = exclusive_scan_serial(&mut v);
        assert_eq!(v, vec![0, 3, 4, 8, 9]);
        assert_eq!(total, 14);
    }

    #[test]
    fn serial_scan_empty_and_singleton() {
        let mut v: Vec<usize> = vec![];
        assert_eq!(exclusive_scan_serial(&mut v), 0);
        let mut v = vec![42];
        assert_eq!(exclusive_scan_serial(&mut v), 42);
        assert_eq!(v, vec![0]);
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Xoshiro256::new(9);
        for n in [0usize, 1, 100, (1 << 14) + 7, 100_000] {
            let base: Vec<usize> = (0..n).map(|_| rng.below(10) as usize).collect();
            let mut a = base.clone();
            let mut b = base.clone();
            let ta = exclusive_scan_serial(&mut a);
            let tb = exclusive_scan(&mut b, 4);
            assert_eq!(ta, tb, "n={n}");
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn team_scan_matches_serial_under_reuse() {
        let team = Team::new(4);
        let mut rng = Xoshiro256::new(11);
        for n in [(1 << 14) + 3, 60_000, 100_000] {
            let base: Vec<usize> = (0..n).map(|_| rng.below(7) as usize).collect();
            let mut a = base.clone();
            let mut b = base.clone();
            let ta = exclusive_scan_serial(&mut a);
            let tb = exclusive_scan_exec(&mut b, 4, Exec::team(&team));
            assert_eq!(ta, tb, "n={n}");
            assert_eq!(a, b, "n={n}");
        }
    }
}
