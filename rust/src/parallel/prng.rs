//! Deterministic PRNGs: SplitMix64 (seeding) and xoshiro256** (streams).
//!
//! Every generator and tie-break in the crate draws from these so runs
//! are reproducible from a single `--seed` (DESIGN.md §8).

/// SplitMix64 — used to expand a user seed into stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse stream RNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's method, bias-free enough for
    /// graph generation at our scales).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply-shift.
        let x = self.next_u64();
        ((x as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Power-law (Zipf-ish) integer in `[1, max]` with exponent `alpha`
    /// via inverse-transform on a truncated Pareto.
    pub fn powerlaw(&mut self, max: u64, alpha: f64) -> u64 {
        let u = self.unit_f64();
        let one_minus = 1.0 - alpha;
        let lo = 1.0f64;
        let hi = max as f64;
        let x = if (one_minus).abs() < 1e-9 {
            // alpha ~ 1: logarithmic inverse transform.
            (lo.ln() + u * (hi.ln() - lo.ln())).exp()
        } else {
            let a = lo.powf(one_minus);
            let b = hi.powf(one_minus);
            (a + u * (b - a)).powf(1.0 / one_minus)
        };
        (x as u64).clamp(1, max)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let a: Vec<u64> = {
            let mut r = SplitMix64::new(1);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = SplitMix64::new(1);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn xoshiro_streams_differ_by_seed() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn below_in_range() {
        let mut r = Xoshiro256::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn unit_f64_in_range() {
        let mut r = Xoshiro256::new(4);
        for _ in 0..10_000 {
            let x = r.unit_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn powerlaw_bounds_and_skew() {
        let mut r = Xoshiro256::new(5);
        let mut small = 0;
        let n = 20_000;
        for _ in 0..n {
            let x = r.powerlaw(1000, 2.2);
            assert!((1..=1000).contains(&x));
            if x <= 3 {
                small += 1;
            }
        }
        // A 2.2-exponent power law is dominated by tiny values.
        assert!(small > n / 2, "power law not skewed: {small}/{n}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
