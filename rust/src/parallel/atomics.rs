//! Atomic floating-point accumulators (CAS loops over atomic bits).
//!
//! GVE-Louvain updates community totals `Σ'` atomically from many
//! threads (Algorithm 2 line 11); std has no `AtomicF64`, so we build
//! one on `AtomicU64` (and an f32 twin used by the GPU simulator's
//! 32-bit hashtable values, Fig 8).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// `f64` cell supporting atomic add/sub/load/store.
#[derive(Debug, Default)]
pub struct AtomicF64 {
    bits: AtomicU64,
}

impl AtomicF64 {
    pub fn new(v: f64) -> Self {
        Self { bits: AtomicU64::new(v.to_bits()) }
    }

    #[inline]
    pub fn load(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    #[inline]
    pub fn store(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed)
    }

    /// Atomically `self += v`; returns the previous value.
    #[inline]
    pub fn fetch_add(&self, v: f64) -> f64 {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.bits.compare_exchange_weak(
                cur, next, Ordering::AcqRel, Ordering::Relaxed) {
                Ok(_) => return f64::from_bits(cur),
                Err(actual) => cur = actual,
            }
        }
    }

    #[inline]
    pub fn fetch_sub(&self, v: f64) -> f64 {
        self.fetch_add(-v)
    }
}

/// `f32` twin of [`AtomicF64`].
#[derive(Debug, Default)]
pub struct AtomicF32 {
    bits: AtomicU32,
}

impl AtomicF32 {
    pub fn new(v: f32) -> Self {
        Self { bits: AtomicU32::new(v.to_bits()) }
    }

    #[inline]
    pub fn load(&self) -> f32 {
        f32::from_bits(self.bits.load(Ordering::Relaxed))
    }

    #[inline]
    pub fn store(&self, v: f32) {
        self.bits.store(v.to_bits(), Ordering::Relaxed)
    }

    #[inline]
    pub fn fetch_add(&self, v: f32) -> f32 {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f32::from_bits(cur) + v).to_bits();
            match self.bits.compare_exchange_weak(
                cur, next, Ordering::AcqRel, Ordering::Relaxed) {
                Ok(_) => return f32::from_bits(cur),
                Err(actual) => cur = actual,
            }
        }
    }
}

/// View a `&mut [f64]` as `&[AtomicF64]` for in-place parallel updates.
///
/// Sound because `AtomicF64` is `repr(transparent)`-compatible in layout
/// (a single `u64`) and the mutable borrow guarantees exclusivity for
/// the duration of the scope that splits it across threads.
pub fn as_atomic_f64(v: &mut [f64]) -> &[AtomicF64] {
    unsafe { &*(v as *mut [f64] as *const [AtomicF64]) }
}

/// View a `&mut [f32]` as `&[AtomicF32]`.
pub fn as_atomic_f32(v: &mut [f32]) -> &[AtomicF32] {
    unsafe { &*(v as *mut [f32] as *const [AtomicF32]) }
}

/// View a `&mut [u32]` as `&[AtomicU32]`.
pub fn as_atomic_u32(v: &mut [u32]) -> &[AtomicU32] {
    unsafe { &*(v as *mut [u32] as *const [AtomicU32]) }
}

/// View a `&mut [u64]` as `&[AtomicU64]`.
pub fn as_atomic_u64(v: &mut [u64]) -> &[AtomicU64] {
    unsafe { &*(v as *mut [u64] as *const [AtomicU64]) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_add_sub_round_trip() {
        let a = AtomicF64::new(1.5);
        assert_eq!(a.fetch_add(2.5), 1.5);
        assert_eq!(a.load(), 4.0);
        a.fetch_sub(1.0);
        assert_eq!(a.load(), 3.0);
    }

    #[test]
    fn f32_add() {
        let a = AtomicF32::new(0.0);
        for _ in 0..100 {
            a.fetch_add(0.5);
        }
        assert_eq!(a.load(), 50.0);
    }

    #[test]
    fn concurrent_f64_sum_is_exactly_n() {
        // Integral values => f64 addition is associative, so the sum is
        // exact regardless of interleaving.
        let cell = AtomicF64::new(0.0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..10_000 {
                        cell.fetch_add(1.0);
                    }
                });
            }
        });
        assert_eq!(cell.load(), 40_000.0);
    }

    #[test]
    fn slice_view_updates_underlying() {
        let mut v = vec![0.0f64; 4];
        {
            let a = as_atomic_f64(&mut v);
            a[2].fetch_add(7.0);
        }
        assert_eq!(v, vec![0.0, 0.0, 7.0, 0.0]);
    }
}
