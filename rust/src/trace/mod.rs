//! Low-overhead span tracing (PR 7).
//!
//! Always compiled, branch-disabled: every instrumentation site costs one
//! relaxed atomic load when no `TraceSession` is active (see `enabled`).
//! When a session is active, spans are recorded into per-thread
//! `TraceSink` ring buffers — preallocated, owner-thread-only pushes
//! (the sink mutex is uncontended on the hot path), `&'static str` names
//! and fixed `[u64; 4]` args so recording never allocates.
//!
//! Structure:
//!
//! - `TraceSink` — one per recording thread. `Team` workers get theirs at
//!   spawn (`parallel::team` holds them in the worker slots); any other
//!   thread lazily self-registers on first span.
//! - `TraceSession` — RAII over the process-global enabled flag. Starting
//!   a session clears every registered sink and flips the flag; `finish`
//!   flips it back and drains all sinks into a merged, time-sorted
//!   `Trace`. One session at a time per process.
//! - `Trace` — the merged event list plus thread labels. Feed it to
//!   `chrome::to_chrome_json` (Perfetto-loadable) or
//!   `report::derive_pass_utilization` (per-pass efficiency table).
//!
//! Timing comes from `clock::Clock` — a monotonic ns counter that
//! defaults to `Instant` and can be swapped for a `MockClock` in tests
//! (the same abstraction `service::IngestBuffer` uses for its
//! max-latency bound).

pub mod chrome;
pub mod clock;
pub mod report;

pub use clock::{Clock, MockClock, SystemClock};

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// Per-sink ring capacity. At ~48 bytes/event this is ~3 MiB per thread,
/// far beyond any pass loop's span count; overflow drops newest and
/// bumps `TraceSink::dropped` rather than reallocating mid-run.
pub const SINK_CAPACITY: usize = 65_536;

/// Process-global "a session is recording" flag. The *only* state a
/// disabled span site reads — one relaxed load, then fall through.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Monotonically increasing id tying a `team.job` span to the
/// `worker.busy` spans it dispatched (arg slot 0 on both sides).
static JOB_SEQ: AtomicU64 = AtomicU64::new(1);

/// True while a `TraceSession` is active. The documented overhead
/// contract: when this returns false, an instrumented site does nothing
/// else — no clock read, no sink lookup, no allocation.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Next dispatch id for correlating team jobs with worker slices.
#[inline]
pub fn next_job_id() -> u64 {
    JOB_SEQ.fetch_add(1, Ordering::Relaxed)
}

/// Coarse event category; becomes the Chrome `cat` field so Perfetto can
/// filter phases independently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Category {
    /// Whole Louvain pass (local-moving + aggregation + bookkeeping).
    Pass,
    /// Local-moving: per-iteration spans and bucket-time instants.
    Move,
    /// Aggregation sub-steps: community-order / offsets / scatter / compact.
    Agg,
    /// Team dispatch: one span per `run_ctx_spec` job.
    Dispatch,
    /// Per-worker busy slices inside a dispatch.
    Worker,
    /// Service epochs: apply / detect / publish.
    Service,
    /// `ScanOrder` bucketing prep.
    Order,
    /// Counter snapshots (instant events carrying `Counters` deltas).
    Counter,
    /// Serving daemon: wire ingest and epoch fan-out (PR 9).
    Server,
}

impl Category {
    pub fn name(self) -> &'static str {
        match self {
            Category::Pass => "pass",
            Category::Move => "move",
            Category::Agg => "agg",
            Category::Dispatch => "dispatch",
            Category::Worker => "worker",
            Category::Service => "service",
            Category::Order => "order",
            Category::Counter => "counter",
            Category::Server => "server",
        }
    }
}

/// What a recorded event is: a closed duration or a point marker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Span,
    Instant,
}

/// One recorded event. `Copy`, fixed-size, `&'static` name — pushing one
/// into a sink is a bounds check and a memcpy.
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    pub name: &'static str,
    pub cat: Category,
    pub kind: EventKind,
    /// Recording thread id (trace-local, dense; 0 = first registrant).
    pub tid: u32,
    /// Start time, ns on the session clock.
    pub start_ns: u64,
    /// Duration in ns (0 for instants).
    pub dur_ns: u64,
    /// Per-name payload; labels come from `chrome::arg_names`.
    pub args: [u64; 4],
}

/// Per-thread event buffer. Held strongly by the global registry (and by
/// `Team` worker slots), so a sink outlives any one session and a
/// long-parked worker's events are never orphaned.
pub struct TraceSink {
    tid: u32,
    label: String,
    events: Mutex<Vec<SpanEvent>>,
    dropped: AtomicU64,
}

impl TraceSink {
    fn new(tid: u32, label: String) -> Self {
        TraceSink {
            tid,
            label,
            events: Mutex::new(Vec::with_capacity(SINK_CAPACITY)),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn tid(&self) -> u32 {
        self.tid
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    /// Events dropped because the ring was full (session lifetime total).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    #[inline]
    fn push(&self, ev: SpanEvent) {
        let mut buf = lock_ignore_poison(&self.events);
        if buf.len() < SINK_CAPACITY {
            buf.push(ev);
        } else {
            drop(buf);
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn clear(&self) {
        lock_ignore_poison(&self.events).clear();
        self.dropped.store(0, Ordering::Relaxed);
    }

    fn drain(&self) -> Vec<SpanEvent> {
        std::mem::take(&mut *lock_ignore_poison(&self.events))
    }
}

fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Registry of every sink ever created. Strong `Arc`s, never removed:
/// team workers park between runs holding their sink, and sessions must
/// still see those sinks next time. Session start clears each sink's
/// *events*, not the registry.
struct Registry {
    sinks: Mutex<Vec<Arc<TraceSink>>>,
    session_active: AtomicBool,
}

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        sinks: Mutex::new(Vec::new()),
        session_active: AtomicBool::new(false),
    })
}

thread_local! {
    static LOCAL_SINK: RefCell<Option<Arc<TraceSink>>> = const { RefCell::new(None) };
}

/// Create a sink labelled `label` and register it globally. `Team::new`
/// calls this per worker slot; the worker installs it via `install_sink`
/// as its first action.
pub fn register_named(label: String) -> Arc<TraceSink> {
    let reg = registry();
    let mut sinks = lock_ignore_poison(&reg.sinks);
    let tid = sinks.len() as u32;
    let sink = Arc::new(TraceSink::new(tid, label));
    sinks.push(sink.clone());
    sink
}

/// Bind `sink` as the calling thread's recording target.
pub fn install_sink(sink: Arc<TraceSink>) {
    LOCAL_SINK.with(|s| *s.borrow_mut() = Some(sink));
}

/// The calling thread's sink, self-registering on first use (label from
/// the OS thread name, or `thread-{tid}`).
fn current_sink() -> Arc<TraceSink> {
    LOCAL_SINK.with(|s| {
        let mut slot = s.borrow_mut();
        if let Some(sink) = slot.as_ref() {
            return sink.clone();
        }
        let label = std::thread::current()
            .name()
            .map(str::to_string)
            .unwrap_or_default();
        let sink = {
            let reg = registry();
            let mut sinks = lock_ignore_poison(&reg.sinks);
            let tid = sinks.len() as u32;
            let label = if label.is_empty() {
                format!("thread-{tid}")
            } else {
                label
            };
            let sink = Arc::new(TraceSink::new(tid, label));
            sinks.push(sink.clone());
            sink
        };
        *slot = Some(sink.clone());
        sink
    })
}

/// Open a span. Returns `None` (and does nothing else) when disabled —
/// the `?`-free call shape is `let _s = trace::span(...)`, which drops
/// the guard (closing the span) at scope end. Mutate `args` through the
/// guard before it drops to attach results computed inside the span.
#[inline]
pub fn span(name: &'static str, cat: Category, args: [u64; 4]) -> Option<SpanGuard> {
    if !enabled() {
        return None;
    }
    Some(SpanGuard {
        name,
        cat,
        args,
        start_ns: clock::now_ns(),
    })
}

/// Record a point event (zero duration) when enabled.
#[inline]
pub fn instant(name: &'static str, cat: Category, args: [u64; 4]) {
    if !enabled() {
        return;
    }
    let sink = current_sink();
    sink.push(SpanEvent {
        name,
        cat,
        kind: EventKind::Instant,
        tid: sink.tid(),
        start_ns: clock::now_ns(),
        dur_ns: 0,
        args,
    });
}

/// RAII span: records its complete event (start + duration) on drop, on
/// whichever thread drops it.
pub struct SpanGuard {
    name: &'static str,
    cat: Category,
    pub args: [u64; 4],
    start_ns: u64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let end = clock::now_ns();
        let sink = current_sink();
        sink.push(SpanEvent {
            name: self.name,
            cat: self.cat,
            kind: EventKind::Span,
            tid: sink.tid(),
            start_ns: self.start_ns,
            dur_ns: end.saturating_sub(self.start_ns),
            args: self.args,
        });
    }
}

/// A finished session's merged output.
pub struct Trace {
    /// All events from all sinks, sorted by (start_ns, tid).
    pub events: Vec<SpanEvent>,
    /// Thread labels, indexed by `SpanEvent::tid`.
    pub threads: Vec<String>,
    /// Events lost to full rings (0 in any sane run).
    pub dropped: u64,
    /// Per-sink drop counts, indexed like `threads` (PR 8: saturated
    /// rings name the thread that lost events instead of counting
    /// silently; also exported as Chrome metadata and mirrored into
    /// the live registry's `gve_trace_dropped_events_total`).
    pub dropped_by_thread: Vec<u64>,
    /// Session bounds on the session clock, ns.
    pub start_ns: u64,
    pub end_ns: u64,
}

impl Trace {
    /// Number of events with the given name (spans + instants).
    pub fn count(&self, name: &str) -> usize {
        self.events.iter().filter(|e| e.name == name).count()
    }

    /// Iterate duration spans with the given name, in start order.
    pub fn spans<'a>(&'a self, name: &'a str) -> impl Iterator<Item = &'a SpanEvent> + 'a {
        self.events
            .iter()
            .filter(move |e| e.kind == EventKind::Span && e.name == name)
    }

    /// Iterate instants with the given name, in start order.
    pub fn instants<'a>(&'a self, name: &'a str) -> impl Iterator<Item = &'a SpanEvent> + 'a {
        self.events
            .iter()
            .filter(move |e| e.kind == EventKind::Instant && e.name == name)
    }

    /// (name → count) map of the trace's structure, timings ignored.
    /// Deterministic across replays of a deterministic run.
    pub fn structure(&self) -> std::collections::BTreeMap<&'static str, usize> {
        let mut m = std::collections::BTreeMap::new();
        for e in &self.events {
            *m.entry(e.name).or_insert(0usize) += 1;
        }
        m
    }

    pub fn wall_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

/// RAII over the global enabled flag. `start` clears all sinks and
/// enables recording; `finish` (or drop) disables it. One at a time —
/// `start` panics if a session is already active, so tests sharing a
/// process must serialize sessions.
pub struct TraceSession {
    start_ns: u64,
    finished: bool,
}

impl TraceSession {
    /// Begin recording. Panics if another session is active in this
    /// process (the enabled flag is global).
    pub fn start() -> TraceSession {
        let reg = registry();
        if reg.session_active.swap(true, Ordering::SeqCst) {
            panic!("trace: a TraceSession is already active in this process");
        }
        {
            let sinks = lock_ignore_poison(&reg.sinks);
            for s in sinks.iter() {
                s.clear();
            }
        }
        let start_ns = clock::now_ns();
        ENABLED.store(true, Ordering::SeqCst);
        TraceSession {
            start_ns,
            finished: false,
        }
    }

    /// Stop recording and merge every sink into a time-sorted `Trace`.
    pub fn finish(mut self) -> Trace {
        self.finished = true;
        ENABLED.store(false, Ordering::SeqCst);
        let end_ns = clock::now_ns();
        let reg = registry();
        let mut events = Vec::new();
        let mut threads = Vec::new();
        let mut dropped = 0u64;
        let mut dropped_by_thread = Vec::new();
        {
            let sinks = lock_ignore_poison(&reg.sinks);
            for s in sinks.iter() {
                events.extend(s.drain());
                dropped += s.dropped();
            }
            // tids are dense registration indices; label table mirrors that.
            threads.resize(sinks.len(), String::new());
            dropped_by_thread.resize(sinks.len(), 0u64);
            for s in sinks.iter() {
                threads[s.tid() as usize] = s.label().to_string();
                dropped_by_thread[s.tid() as usize] = s.dropped();
            }
        }
        // Mirror the session's losses into the live registry (PR 8):
        // a scraper sees saturation without parsing any trace file.
        crate::obs::sites::trace_dropped_events().add(dropped);
        events.sort_by_key(|e| (e.start_ns, e.tid));
        reg.session_active.store(false, Ordering::SeqCst);
        Trace {
            events,
            threads,
            dropped,
            dropped_by_thread,
            start_ns: self.start_ns,
            end_ns,
        }
    }
}

impl Drop for TraceSession {
    fn drop(&mut self) {
        if !self.finished {
            ENABLED.store(false, Ordering::SeqCst);
            registry().session_active.store(false, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests here avoid TraceSession (the flag is process-global and
    // `cargo test` is multithreaded); session behaviour is covered by
    // the serialized integration tests in tests/trace.rs.

    #[test]
    fn disabled_span_site_is_none() {
        assert!(!enabled());
        assert!(span("x", Category::Pass, [0; 4]).is_none());
        instant("y", Category::Counter, [0; 4]); // no-op, must not panic
    }

    #[test]
    fn sink_ring_drops_newest_past_capacity() {
        let sink = TraceSink::new(0, "t".into());
        let ev = SpanEvent {
            name: "e",
            cat: Category::Pass,
            kind: EventKind::Instant,
            tid: 0,
            start_ns: 0,
            dur_ns: 0,
            args: [0; 4],
        };
        for _ in 0..SINK_CAPACITY + 7 {
            sink.push(ev);
        }
        assert_eq!(sink.dropped(), 7);
        assert_eq!(sink.drain().len(), SINK_CAPACITY);
        assert_eq!(sink.dropped(), 7); // drain does not reset the counter
        sink.clear();
        assert_eq!(sink.dropped(), 0);
    }

    #[test]
    fn registry_assigns_dense_tids() {
        let a = register_named("a".into());
        let b = register_named("b".into());
        assert!(b.tid() > a.tid());
        assert_eq!(a.label(), "a");
    }

    #[test]
    fn job_ids_increase() {
        let x = next_job_id();
        let y = next_job_id();
        assert!(y > x);
    }
}
