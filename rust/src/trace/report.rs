//! Derived per-pass utilization numbers (PR 7).
//!
//! Turns a raw `Trace` into the quantities the paper argues from:
//! per-pass parallelism efficiency (Σ worker-busy / (wall × threads)),
//! per-bucket low/mid/high scan time (from the PR-6 `ScanOrder`
//! bucketing, recorded as `move.buckets` instants), and the small-path
//! fraction per pass (from the per-pass `Counters` snapshot). The
//! aligned table goes through `coordinator::report::Table`, same as
//! every other CLI report in the repo.

use super::{EventKind, Trace};
use crate::coordinator::metrics::fmt_ns;
use crate::coordinator::report::Table;
use crate::louvain::LouvainResult;

/// Utilization numbers for one Louvain pass, derived purely from spans.
#[derive(Clone, Copy, Debug, Default)]
pub struct PassUtil {
    /// Pass index (arg 0 of the `pass` span).
    pub pass: u64,
    /// Pass span duration.
    pub wall_ns: u64,
    /// Σ over workers of `worker.busy` time clipped to the pass window.
    pub busy_ns: u64,
    /// busy / (wall × threads), clamped to [0, 1].
    pub efficiency: f64,
    /// Accumulated low/mid/high bucket scan ns (`move.buckets` instants).
    pub bucket_ns: [u64; 3],
}

/// Per-pass utilization from the raw span stream. `threads` is the
/// parallelism the run was configured with (the efficiency denominator);
/// busy slices recorded by *any* worker inside a pass window count, so
/// inline single-thread execution shows up as efficiency ≈ 1/threads
/// only if threads > 1 went idle — exactly the signal we want.
pub fn derive_pass_utilization(trace: &Trace, threads: usize) -> Vec<PassUtil> {
    let threads = threads.max(1) as u64;
    let mut utils: Vec<PassUtil> = Vec::new();
    for p in trace.spans("pass") {
        let (lo, hi) = (p.start_ns, p.start_ns.saturating_add(p.dur_ns));
        let mut u = PassUtil {
            pass: p.args[0],
            wall_ns: p.dur_ns,
            ..PassUtil::default()
        };
        for w in &trace.events {
            match (w.kind, w.name) {
                (EventKind::Span, "worker.busy") => {
                    let (ws, we) = (w.start_ns, w.start_ns.saturating_add(w.dur_ns));
                    let clipped = we.min(hi).saturating_sub(ws.max(lo));
                    u.busy_ns += clipped;
                }
                (EventKind::Instant, "move.buckets") if w.start_ns >= lo && w.start_ns <= hi => {
                    u.bucket_ns[0] += w.args[1];
                    u.bucket_ns[1] += w.args[2];
                    u.bucket_ns[2] += w.args[3];
                }
                _ => {}
            }
        }
        let denom = (u.wall_ns.max(1) * threads) as f64;
        u.efficiency = (u.busy_ns as f64 / denom).min(1.0);
        utils.push(u);
    }
    utils.sort_by_key(|u| u.pass);
    utils
}

/// Mean per-pass efficiency (the single number bench cells carry).
pub fn mean_efficiency(utils: &[PassUtil]) -> f64 {
    if utils.is_empty() {
        return 0.0;
    }
    utils.iter().map(|u| u.efficiency).sum::<f64>() / utils.len() as f64
}

/// One-line `label=count` summary of non-zero per-sink drop counts,
/// for the CLIs' session summaries (PR 8). Empty string when no sink
/// dropped anything.
pub fn dropped_summary(trace: &Trace) -> String {
    let mut out = String::new();
    for (tid, &d) in trace.dropped_by_thread.iter().enumerate() {
        if d == 0 {
            continue;
        }
        if !out.is_empty() {
            out.push_str(", ");
        }
        let label = trace.threads.get(tid).map(String::as_str).unwrap_or("");
        if label.is_empty() {
            out.push_str(&format!("tid{tid}={d}"));
        } else {
            out.push_str(&format!("{label}={d}"));
        }
    }
    out
}

fn pct(num: u64, den: u64) -> String {
    if den == 0 {
        "-".to_string()
    } else {
        format!("{:.1}", 100.0 * num as f64 / den as f64)
    }
}

/// Aligned per-pass table: wall time, effective width (the adaptive
/// engine's per-pass choice, PR 10), parallelism efficiency, small-path
/// fraction (from the per-pass `Counters` snapshot in `PassStats`), and
/// the low/mid/high bucket time split when degree-bucketed dealing ran.
pub fn utilization_table(result: &LouvainResult, trace: &Trace, threads: usize) -> Table {
    let utils = derive_pass_utilization(trace, threads);
    let mut t = Table::new(
        "per-pass utilization",
        &[
            "pass", "|V'|", "iters", "w", "wall", "eff%", "small%", "lo%", "mid%", "hi%",
        ],
    );
    for (i, ps) in result.pass_stats.iter().enumerate() {
        let u = utils
            .iter()
            .find(|u| u.pass as usize == i)
            .copied()
            .unwrap_or_default();
        let scans = ps.counters.small_path_scans + ps.counters.large_path_scans;
        let bucket_total: u64 = u.bucket_ns.iter().sum();
        t.row(vec![
            i.to_string(),
            ps.vertices.to_string(),
            ps.iterations.to_string(),
            ps.effective_threads.to_string(),
            fmt_ns(if u.wall_ns > 0 {
                u.wall_ns
            } else {
                ps.move_ns + ps.agg_ns + ps.other_ns
            }),
            format!("{:.1}", 100.0 * u.efficiency),
            pct(ps.counters.small_path_scans, scans),
            pct(u.bucket_ns[0], bucket_total),
            pct(u.bucket_ns[1], bucket_total),
            pct(u.bucket_ns[2], bucket_total),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Category, SpanEvent};

    fn span(name: &'static str, tid: u32, start: u64, dur: u64, args: [u64; 4]) -> SpanEvent {
        SpanEvent {
            name,
            cat: Category::Pass,
            kind: EventKind::Span,
            tid,
            start_ns: start,
            dur_ns: dur,
            args,
        }
    }

    fn instant(name: &'static str, start: u64, args: [u64; 4]) -> SpanEvent {
        SpanEvent {
            name,
            cat: Category::Move,
            kind: EventKind::Instant,
            tid: 0,
            start_ns: start,
            dur_ns: 0,
            args,
        }
    }

    #[test]
    fn efficiency_sums_clipped_busy_time() {
        // Pass [0, 1000); two workers busy 400ns each fully inside, one
        // slice half-outside contributing 100.
        let trace = Trace {
            events: vec![
                span("pass", 0, 0, 1000, [0, 0, 0, 0]),
                span("worker.busy", 1, 100, 400, [1, 0, 0, 0]),
                span("worker.busy", 2, 100, 400, [1, 1, 0, 0]),
                span("worker.busy", 3, 900, 200, [2, 2, 0, 0]),
                instant("move.buckets", 500, [0, 10, 20, 70]),
            ],
            threads: vec![],
            dropped: 0,
            dropped_by_thread: vec![],
            start_ns: 0,
            end_ns: 1000,
        };
        let utils = derive_pass_utilization(&trace, 2);
        assert_eq!(utils.len(), 1);
        let u = &utils[0];
        assert_eq!(u.wall_ns, 1000);
        assert_eq!(u.busy_ns, 400 + 400 + 100);
        assert!((u.efficiency - 900.0 / 2000.0).abs() < 1e-9);
        assert_eq!(u.bucket_ns, [10, 20, 70]);
        assert!((mean_efficiency(&utils) - u.efficiency).abs() < 1e-12);
    }

    #[test]
    fn efficiency_clamps_at_one() {
        let trace = Trace {
            events: vec![
                span("pass", 0, 0, 100, [0, 0, 0, 0]),
                span("worker.busy", 1, 0, 100, [1, 0, 0, 0]),
                span("worker.busy", 2, 0, 100, [1, 1, 0, 0]),
            ],
            threads: vec![],
            dropped: 0,
            dropped_by_thread: vec![],
            start_ns: 0,
            end_ns: 100,
        };
        let utils = derive_pass_utilization(&trace, 1);
        assert_eq!(utils[0].efficiency, 1.0);
    }

    #[test]
    fn dropped_summary_names_saturated_sinks_only() {
        let trace = Trace {
            events: vec![],
            threads: vec!["main".into(), String::new(), "gve-team-2".into()],
            dropped: 12,
            dropped_by_thread: vec![0, 5, 7],
            start_ns: 0,
            end_ns: 0,
        };
        assert_eq!(dropped_summary(&trace), "tid1=5, gve-team-2=7");
    }
}
