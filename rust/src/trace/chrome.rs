//! Chrome trace-event JSON export (PR 7).
//!
//! Emits the subset of the trace-event format Perfetto and
//! `chrome://tracing` load: an object `{"traceEvents": [...]}` with
//! per-thread `"M"` (thread_name) metadata records, `"X"` (complete)
//! records for spans, and `"i"` (instant) records for point events.
//! Timestamps are microseconds (f64) rebased to the session start so
//! traces open at t=0. Hand-rolled writer — the crate has no JSON dep.

use super::{EventKind, SpanEvent, Trace};
use std::fmt::Write as _;

/// Human-readable labels for the fixed `[u64; 4]` arg slots, per event
/// name. Unlisted names fall back to `a0..a3`. Keep in sync with the
/// instrumentation sites.
pub fn arg_names(name: &str) -> [&'static str; 4] {
    match name {
        "pass" => ["pass", "vertices", "edges", "width"],
        "pass.counters" => ["pass", "width", "small_path_scans", "large_path_scans"],
        "move" => ["pass", "iterations", "moves", ""],
        "move.iter" => ["iter", "processed", "moves", "pruned"],
        "move.iter.counters" => ["iter", "small_path_scans", "large_path_scans", "table_ops"],
        "move.buckets" => ["iter", "lo_ns", "mid_ns", "hi_ns"],
        "agg" => ["pass", "communities", "", ""],
        "agg.community_order" => ["communities", "", "", ""],
        "agg.offsets" => ["communities", "", "", ""],
        "agg.scatter" => ["communities", "", "", ""],
        "agg.compact" => ["communities", "edges_out", "", ""],
        "scan_order.build" => ["n", "lo_end", "mid_end", "parallel"],
        "team.job" => ["job", "workers", "items", ""],
        "worker.busy" => ["job", "tid", "chunks", ""],
        "epoch.apply" => ["epoch", "batch_ops", "", ""],
        "epoch.detect" => ["epoch", "affected_seeded", "passes", ""],
        "epoch.publish" => ["epoch", "vertices", "", ""],
        "server.ingest" => ["conn", "ops", "rejected", ""],
        "server.publish" => ["epoch", "changed", "subscribers", "full"],
        _ => ["a0", "a1", "a2", "a3"],
    }
}

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

fn write_args(out: &mut String, ev: &SpanEvent) {
    let names = arg_names(ev.name);
    out.push_str("{");
    let mut first = true;
    for (i, label) in names.iter().enumerate() {
        if label.is_empty() {
            continue;
        }
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(out, "\"{}\":{}", label, ev.args[i]);
    }
    out.push('}');
}

/// Serialize a finished trace. ~150 bytes per event; a full Louvain run
/// on a scale-13 graph is a few hundred KiB.
pub fn to_chrome_json(trace: &Trace) -> String {
    let mut out = String::with_capacity(trace.events.len() * 160 + 1024);
    out.push_str("{\"traceEvents\":[\n");
    let mut first = true;
    // Thread-name metadata first so viewers label tracks before events.
    for (tid, label) in trace.threads.iter().enumerate() {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        let _ = write!(
            out,
            "{{\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\"name\":\"thread_name\",\"args\":{{\"name\":\""
        );
        escape_into(&mut out, if label.is_empty() { "thread" } else { label });
        out.push_str("\"}}");
    }
    for ev in &trace.events {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        let ts_us = ev.start_ns.saturating_sub(trace.start_ns) as f64 / 1000.0;
        match ev.kind {
            EventKind::Span => {
                let dur_us = ev.dur_ns as f64 / 1000.0;
                let _ = write!(
                    out,
                    "{{\"ph\":\"X\",\"pid\":1,\"tid\":{},\"name\":\"{}\",\"cat\":\"{}\",\"ts\":{:.3},\"dur\":{:.3},\"args\":",
                    ev.tid,
                    ev.name,
                    ev.cat.name(),
                    ts_us,
                    dur_us
                );
            }
            EventKind::Instant => {
                let _ = write!(
                    out,
                    "{{\"ph\":\"i\",\"pid\":1,\"tid\":{},\"name\":\"{}\",\"cat\":\"{}\",\"ts\":{:.3},\"s\":\"t\",\"args\":",
                    ev.tid,
                    ev.name,
                    ev.cat.name(),
                    ts_us
                );
            }
        }
        write_args(&mut out, ev);
        out.push('}');
    }
    // Top-level metadata (`otherData`, ignored by the event parser):
    // surface ring saturation in the export itself (PR 8) so a trace
    // with holes says so without the capturing CLI's stderr at hand.
    out.push_str("\n],\"otherData\":{\"dropped_events\":");
    let _ = write!(out, "{}", trace.dropped);
    out.push_str(",\"dropped_by_thread\":{");
    let mut first_drop = true;
    for (tid, &d) in trace.dropped_by_thread.iter().enumerate() {
        if d == 0 {
            continue;
        }
        if !first_drop {
            out.push(',');
        }
        first_drop = false;
        let _ = write!(out, "\"{}\":{d}", thread_key(trace, tid));
    }
    out.push_str("}}}\n");
    out
}

/// Label for the dropped-by-thread map (falls back to the tid).
fn thread_key(trace: &Trace, tid: usize) -> String {
    match trace.threads.get(tid) {
        Some(l) if !l.is_empty() => {
            let mut out = String::new();
            escape_into(&mut out, l);
            out
        }
        _ => tid.to_string(),
    }
}

/// Write the Chrome JSON to `path`.
pub fn write(trace: &Trace, path: &str) -> std::io::Result<()> {
    std::fs::write(path, to_chrome_json(trace))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Category;

    fn ev(name: &'static str, kind: EventKind, start: u64, dur: u64) -> SpanEvent {
        SpanEvent {
            name,
            cat: Category::Pass,
            kind,
            tid: 0,
            start_ns: start,
            dur_ns: dur,
            args: [1, 2, 3, 4],
        }
    }

    #[test]
    fn json_shape_has_metadata_and_events() {
        let trace = Trace {
            events: vec![
                ev("pass", EventKind::Span, 1000, 5000),
                ev("pass.counters", EventKind::Instant, 6000, 0),
            ],
            threads: vec!["main".into()],
            dropped: 0,
            dropped_by_thread: vec![0],
            start_ns: 1000,
            end_ns: 10_000,
        };
        let json = to_chrome_json(&trace);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"otherData\":{\"dropped_events\":0,\"dropped_by_thread\":{}}"));
        assert!(json.contains("\"ph\":\"M\""));
        assert!(json.contains("\"thread_name\""));
        // Span rebased to session start: ts 0.000, dur 5.000 µs.
        assert!(json.contains("\"ph\":\"X\",\"pid\":1,\"tid\":0,\"name\":\"pass\""));
        assert!(json.contains("\"ts\":0.000"));
        assert!(json.contains("\"dur\":5.000"));
        assert!(json.contains("\"ph\":\"i\""));
        // Named args, empty slots skipped.
        assert!(json.contains("\"pass\":1,\"vertices\":2,\"edges\":3"));
        assert!(!json.contains("\"\":"));
    }

    #[test]
    fn labels_are_escaped() {
        let trace = Trace {
            events: vec![],
            threads: vec!["we\"ird\\name".into()],
            dropped: 0,
            dropped_by_thread: vec![0],
            start_ns: 0,
            end_ns: 0,
        };
        let json = to_chrome_json(&trace);
        assert!(json.contains("we\\\"ird\\\\name"));
    }

    #[test]
    fn dropped_counts_appear_in_metadata_keyed_by_thread() {
        let trace = Trace {
            events: vec![],
            threads: vec!["main".into(), "gve-team-1".into()],
            dropped: 7,
            dropped_by_thread: vec![0, 7],
            start_ns: 0,
            end_ns: 0,
        };
        let json = to_chrome_json(&trace);
        assert!(json.contains("\"dropped_events\":7"));
        assert!(json.contains("\"dropped_by_thread\":{\"gve-team-1\":7}"));
        // Zero-drop sinks stay out of the map.
        assert!(!json.contains("\"main\":0"));
    }
}
