//! Injectable monotonic clock (PR 7 satellite).
//!
//! One abstraction serves two consumers: the trace subsystem's span
//! timestamps and `service::IngestBuffer`'s max-latency flush bound
//! (the ROADMAP mock-clock item). Production code never constructs a
//! clock explicitly — `SystemClock` is the default everywhere — and the
//! trace hot path doesn't even go through the trait: `now_ns()` reads a
//! process-epoch `Instant` directly unless a test installed an override.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Monotonic nanosecond source. `Send + Sync` so one instance can back
/// an `IngestBuffer` on the writer thread and assertions on the test
/// thread.
pub trait Clock: Send + Sync {
    fn now_ns(&self) -> u64;
}

/// Real time: nanoseconds since the first call in this process.
#[derive(Clone, Copy, Debug, Default)]
pub struct SystemClock;

impl Clock for SystemClock {
    fn now_ns(&self) -> u64 {
        process_now_ns()
    }
}

/// Test clock: time advances only when told to.
#[derive(Debug, Default)]
pub struct MockClock {
    ns: AtomicU64,
}

impl MockClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance(&self, d: Duration) {
        self.ns
            .fetch_add(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX), Ordering::SeqCst);
    }

    pub fn set_ns(&self, ns: u64) {
        self.ns.store(ns, Ordering::SeqCst);
    }
}

impl Clock for MockClock {
    fn now_ns(&self) -> u64 {
        self.ns.load(Ordering::SeqCst)
    }
}

fn process_epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since process trace epoch (first use). Saturates at
/// u64::MAX after ~584 years of uptime.
pub fn process_now_ns() -> u64 {
    u64::try_from(process_epoch().elapsed().as_nanos()).unwrap_or(u64::MAX)
}

static CUSTOM_CLOCK_SET: AtomicBool = AtomicBool::new(false);

fn custom_clock_slot() -> &'static Mutex<Option<Arc<dyn Clock>>> {
    static SLOT: OnceLock<Mutex<Option<Arc<dyn Clock>>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Install a clock override for the trace subsystem (tests only — this
/// puts a mutex on every timestamp read). Pass `None` to restore the
/// default `Instant` path.
pub fn set_trace_clock(clock: Option<Arc<dyn Clock>>) {
    let mut slot = match custom_clock_slot().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    CUSTOM_CLOCK_SET.store(clock.is_some(), Ordering::SeqCst);
    *slot = clock;
}

/// Trace-internal timestamp: default path is one relaxed load + an
/// `Instant::elapsed`, no trait object in sight.
#[inline]
pub(crate) fn now_ns() -> u64 {
    if !CUSTOM_CLOCK_SET.load(Ordering::Relaxed) {
        return process_now_ns();
    }
    let slot = match custom_clock_slot().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    match slot.as_ref() {
        Some(c) => c.now_ns(),
        None => process_now_ns(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_is_monotonic() {
        let c = SystemClock;
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn mock_clock_advances_only_when_told() {
        let c = MockClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance(Duration::from_millis(5));
        assert_eq!(c.now_ns(), 5_000_000);
        c.set_ns(42);
        assert_eq!(c.now_ns(), 42);
        c.advance(Duration::from_nanos(8));
        assert_eq!(c.now_ns(), 50);
    }
}
