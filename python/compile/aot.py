"""AOT pipeline: lower the L2 graphs to HLO *text* for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, one per tile class plus the modularity evaluator:

  artifacts/louvain_scan_tv{TV}_md{MD}.hlo.txt
  artifacts/modularity_c{C}.hlo.txt
  artifacts/manifest.txt      name<TAB>kind<TAB>shape-params per line

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels.louvain_scan import TILE_CLASSES

MODULARITY_CHUNK = 4096


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True: the Rust
    side unwraps with to_tuple{N}())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_move_step(tv: int, md: int) -> str:
    specs = model.move_step_specs(tv, md)
    return to_hlo_text(jax.jit(model.move_step).lower(*specs))


def lower_modularity(c: int) -> str:
    specs = model.modularity_specs(c)
    return to_hlo_text(jax.jit(model.modularity_chunk).lower(*specs))


def build_all(out_dir: str) -> list[tuple[str, str, str]]:
    """Lower every artifact; returns manifest rows (file, kind, params)."""
    os.makedirs(out_dir, exist_ok=True)
    rows: list[tuple[str, str, str]] = []
    for tv, md in TILE_CLASSES:
        name = f"louvain_scan_tv{tv}_md{md}.hlo.txt"
        text = lower_move_step(tv, md)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        rows.append((name, "move_step", f"tv={tv} md={md}"))
        print(f"wrote {name} ({len(text)} chars)")
    name = f"modularity_c{MODULARITY_CHUNK}.hlo.txt"
    text = lower_modularity(MODULARITY_CHUNK)
    with open(os.path.join(out_dir, name), "w") as f:
        f.write(text)
    rows.append((name, "modularity", f"c={MODULARITY_CHUNK}"))
    print(f"wrote {name} ({len(text)} chars)")
    return rows


def write_manifest(out_dir: str, rows) -> None:
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for name, kind, params in rows:
            f.write(f"{name}\t{kind}\t{params}\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    rows = build_all(args.out_dir)
    write_manifest(args.out_dir, rows)
    print(f"manifest: {len(rows)} artifacts in {args.out_dir}")


if __name__ == "__main__":
    main()
