"""L2: the jax compute graph around the Pallas community-scan kernel.

Two AOT-exported entry points (both pure functions, fixed shapes so the
Rust PJRT client compiles one executable per tile class):

  * ``move_step`` — one lock-step local-moving step over a tile: the
    Pallas scan picks each vertex's best community, then the step is
    post-processed *in-graph*: moves with non-positive dQ are rejected
    and the total accepted delta-modularity of the tile is reduced.
    Outputs: (best_comm i32[TV], best_dq f32[TV], accept i32[TV],
    dq_total f32[1]).
  * ``modularity_chunk`` — partial modularity over a zero-padded chunk
    of communities (Eq. 1), reduced in f32 on-device, summed on host.

The Rust coordinator owns everything else (tiles, Sigma bookkeeping,
convergence, aggregation): Python never runs at serve time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.louvain_scan import louvain_scan, pack_params  # noqa: F401
from .kernels.ref import NEG_INF


def move_step(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr, sigma_self,
              params):
    """One lock-step tile move step. See module docstring for the contract."""
    best_comm, best_dq = louvain_scan(nbr_comm, nbr_wt, self_comm, ktot,
                                      sigma_nbr, sigma_self, params)
    # Accept only strictly-improving moves to a different community
    # (Algorithm 2 line 10 / Algorithm 5 line 23).
    accept = (best_dq > 0.0) & (best_comm != self_comm)
    dq_total = jnp.sum(jnp.where(accept, best_dq, 0.0), dtype=jnp.float32)
    out_comm = jnp.where(accept, best_comm, self_comm).astype(jnp.int32)
    return (out_comm,
            best_dq.astype(jnp.float32),
            accept.astype(jnp.int32),
            dq_total.reshape((1,)))


def modularity_chunk(sigma, big_sigma, minv):
    """Partial modularity of a community chunk.

    sigma:    f32[C] total intra-community edge weight (sigma_c)
    big_sigma:f32[C] total edge weight associated with c (Sigma_c)
    minv:     f32[1] = [1 / (2m)]
    Returns f32[1]: sum_c sigma_c/(2m) - (Sigma_c/(2m))^2.
    """
    s = sigma * minv[0]
    t = big_sigma * minv[0]
    return jnp.sum(s - t * t, dtype=jnp.float32).reshape((1,))


def move_step_specs(tv, md):
    """ShapeDtypeStructs for jit-lowering move_step at a tile class."""
    f32, i32 = jnp.float32, jnp.int32
    return (
        jax.ShapeDtypeStruct((tv, md), i32),   # nbr_comm
        jax.ShapeDtypeStruct((tv, md), f32),   # nbr_wt
        jax.ShapeDtypeStruct((tv,), i32),      # self_comm
        jax.ShapeDtypeStruct((tv,), f32),      # ktot
        jax.ShapeDtypeStruct((tv, md), f32),   # sigma_nbr
        jax.ShapeDtypeStruct((tv,), f32),      # sigma_self
        jax.ShapeDtypeStruct((1, 2), f32),     # params [m, pick_less]
    )


def modularity_specs(c):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((c,), f32),
        jax.ShapeDtypeStruct((c,), f32),
        jax.ShapeDtypeStruct((1,), f32),
    )


__all__ = [
    "move_step", "modularity_chunk", "move_step_specs", "modularity_specs",
    "pack_params", "NEG_INF",
]
