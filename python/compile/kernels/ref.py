"""Pure-jnp oracle for the Louvain community-scan tile.

This is the CORRECTNESS reference for the Pallas kernel in
``louvain_scan.py``.  It implements the same tile contract with plain
vectorized jax.numpy (no pallas), using the delta-modularity formula of
the paper (Eq. 2):

    dQ_{i: d->c} = (1/m) (K_{i->c} - K_{i->d})
                 - K_i / (2 m^2) (K_i + Sigma_c - Sigma_d)

Tile contract (one tile = TV vertices, degree padded to MD slots):

  nbr_comm   i32[TV, MD]  community id of each neighbour slot (-1 = padding)
  nbr_wt     f32[TV, MD]  edge weight of each slot (0 for padding; the host
                          zeroes self-loops when building local-moving tiles)
  self_comm  i32[TV]      current community of the tile vertex
  ktot       f32[TV]      weighted degree K_i of the tile vertex
  sigma_nbr  f32[TV, MD]  Sigma_c of each candidate slot's community,
                          gathered host-side before the call
  sigma_self f32[TV]      Sigma_d of the vertex's current community
  m          f32          total edge weight of the graph
  pick_less  bool         Pick-Less mode: only allow moves to a community
                          with a *smaller* id than the current one

Returns:

  best_comm  i32[TV]  the community maximizing dQ (current community when no
                      admissible candidate exists)
  best_dq    f32[TV]  the corresponding dQ (NEG_INF when no candidate)

Tie-break: the first maximal slot in neighbour order (argmax semantics);
the Rust tile builders use the same slot order so results round-trip.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Sentinel for "no admissible candidate".  Finite so it survives f32
# round-trips through HLO literals.
NEG_INF = np.float32(-3.0e38)

PAD = -1  # padding community id


def scan_tile_ref(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr, sigma_self,
                  m, pick_less):
    """Vectorized reference scan over a whole tile. Returns (best_comm, best_dq)."""
    nbr_comm = jnp.asarray(nbr_comm, jnp.int32)
    nbr_wt = jnp.asarray(nbr_wt, jnp.float32)
    self_comm = jnp.asarray(self_comm, jnp.int32)
    ktot = jnp.asarray(ktot, jnp.float32)
    sigma_nbr = jnp.asarray(sigma_nbr, jnp.float32)
    sigma_self = jnp.asarray(sigma_self, jnp.float32)
    m = jnp.float32(m)

    valid = nbr_comm != PAD  # [TV, MD]
    # K_{i->c_k}: total weight of slots sharing slot k's community.
    same = (nbr_comm[:, :, None] == nbr_comm[:, None, :]) & valid[:, :, None]
    k_cand = jnp.einsum("vl,vlk->vk",
                        nbr_wt * valid, same.astype(jnp.float32))
    # K_{i->d}: weight to the current community.
    to_self = (nbr_comm == self_comm[:, None]) & valid
    k_self = jnp.sum(nbr_wt * to_self, axis=1)  # [TV]

    dq = (k_cand - k_self[:, None]) / m - (
        ktot[:, None]
        * (ktot[:, None] + sigma_nbr - sigma_self[:, None])
        / (2.0 * m * m)
    )

    admissible = valid & (nbr_comm != self_comm[:, None])
    admissible = jnp.where(pick_less,
                           admissible & (nbr_comm < self_comm[:, None]),
                           admissible)

    masked = jnp.where(admissible, dq, NEG_INF)
    best_idx = jnp.argmax(masked, axis=1)  # first max in slot order
    best_dq = jnp.take_along_axis(masked, best_idx[:, None], axis=1)[:, 0]
    best_comm = jnp.take_along_axis(nbr_comm, best_idx[:, None], axis=1)[:, 0]
    # No admissible candidate -> stay put.
    none = best_dq <= NEG_INF / 2
    best_comm = jnp.where(none, self_comm, best_comm)
    return best_comm.astype(jnp.int32), best_dq.astype(jnp.float32)


def scan_tile_ref_loop(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr,
                       sigma_self, m, pick_less):
    """Scalar-loop numpy reference (slow, maximally independent).

    Used by tests to cross-check both the vectorized reference and the
    Pallas kernel; mirrors the per-thread hashtable scan of GVE-Louvain.
    """
    nbr_comm = np.asarray(nbr_comm, np.int32)
    nbr_wt = np.asarray(nbr_wt, np.float32)
    self_comm = np.asarray(self_comm, np.int32)
    ktot = np.asarray(ktot, np.float32)
    sigma_nbr = np.asarray(sigma_nbr, np.float32)
    sigma_self = np.asarray(sigma_self, np.float32)
    tv, md = nbr_comm.shape
    out_c = np.empty(tv, np.int32)
    out_q = np.empty(tv, np.float32)
    m = np.float32(m)
    for v in range(tv):
        # Accumulate K_{i->c} per distinct community (the "hashtable").
        acc: dict = {}
        for l in range(md):
            c = int(nbr_comm[v, l])
            if c == PAD:
                continue
            acc[c] = np.float32(acc.get(c, np.float32(0.0)) + nbr_wt[v, l])
        k_self = acc.get(int(self_comm[v]), np.float32(0.0))
        best_q = NEG_INF
        best_c = int(self_comm[v])
        for l in range(md):  # slot order defines the tie-break
            c = int(nbr_comm[v, l])
            if c == PAD or c == int(self_comm[v]):
                continue
            if pick_less and c >= int(self_comm[v]):
                continue
            dq = np.float32(
                np.float32(acc[c] - k_self) / m
                - ktot[v] * (ktot[v] + sigma_nbr[v, l] - sigma_self[v])
                / np.float32(2.0 * m * m)
            )
            if dq > best_q:
                best_q, best_c = dq, c
        out_c[v], out_q[v] = best_c, best_q
    return out_c, out_q


def modularity_ref(sigma, big_sigma, m):
    """Partial modularity over a chunk of communities (Eq. 1).

    Q_chunk = sum_c [ sigma_c / (2m) - (Sigma_c / (2m))^2 ]; the host sums
    chunks. Zero-padded entries contribute 0.
    """
    sigma = np.asarray(sigma, np.float64)
    big_sigma = np.asarray(big_sigma, np.float64)
    m = float(m)
    return float(np.sum(sigma / (2 * m) - (big_sigma / (2 * m)) ** 2))
