"""L1 Pallas kernel: the Louvain community-scan tile.

This is the TPU re-expression of nu-Louvain's ``scanCommunities`` +
best-community selection (paper Algorithm 5 lines 16-22, Algorithm 7).
The CUDA version accumulates (community -> weight) in a per-vertex
open-addressing hashtable probed by 32-thread warps.  TPUs have no
per-lane scatter into scratchpad, so the scan is made *dense*
(DESIGN.md §Hardware-Adaptation):

  * one grid step processes one vertex row of the (TV, MD) tile;
  * the hashtable accumulation becomes a compare one-hot matrix
    ``C[l, k] = (comm_l == comm_k)`` contracted against the weight row —
    an (1, MD) x (MD, MD) matmul, i.e. MXU work instead of irregular
    probing;
  * padding masks, self-community exclusion, delta-modularity and the
    Pick-Less constraint are lane-wise VPU ops;
  * the thread- vs block-per-vertex switch degree of the paper becomes
    tile-class selection (MD in {32, 128, 512}) on the Rust side.

VMEM footprint per grid step: 2*MD*4 B inputs + MD*MD*4 B compare matrix
(1 MiB at MD=512), well inside a TPU core's ~16 MiB VMEM for all classes.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret-mode lowers to plain HLO which both jax-CPU and
the Rust PJRT client execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF, PAD

# Tile classes: (TV, MD) — mirrors the paper's two-kernel partitioning
# (Figs 9-10).  Vertices are routed by degree to the smallest class that
# fits; MD=32 plays the "thread-per-vertex" role, MD>=128 the
# "block-per-vertex" role.
TILE_CLASSES = ((256, 32), (64, 128), (16, 512))


def _scan_kernel(nbr_comm_ref, nbr_wt_ref, self_comm_ref, ktot_ref,
                 sigma_nbr_ref, sigma_self_ref, params_ref,
                 best_comm_ref, best_dq_ref):
    """One vertex row: dense community scan + masked argmax.

    params_ref: f32[1, 2] = [m, pick_less_flag] (broadcast to every step).
    """
    comm = nbr_comm_ref[0, :]          # i32[MD]
    wt = nbr_wt_ref[0, :]              # f32[MD]
    self_comm = self_comm_ref[0]       # i32
    ktot = ktot_ref[0]                 # f32
    sigma_nbr = sigma_nbr_ref[0, :]    # f32[MD]
    sigma_self = sigma_self_ref[0]     # f32
    m = params_ref[0, 0]
    pick_less = params_ref[0, 1] > 0.5

    valid = comm != PAD
    # Dense "hashtable": C[l, k] = slot l and slot k share a community.
    same = (comm[:, None] == comm[None, :]) & valid[:, None]
    # K_{i->c_k} = w . C  — the MXU contraction.
    k_cand = jnp.dot((wt * valid).astype(jnp.float32),
                     same.astype(jnp.float32),
                     preferred_element_type=jnp.float32)  # f32[MD]
    k_self = jnp.sum(jnp.where(comm == self_comm, wt, 0.0) * valid)

    dq = (k_cand - k_self) / m - ktot * (ktot + sigma_nbr - sigma_self) / (
        2.0 * m * m)

    admissible = valid & (comm != self_comm)
    admissible = jnp.where(pick_less, admissible & (comm < self_comm),
                           admissible)
    masked = jnp.where(admissible, dq, NEG_INF)

    best_idx = jnp.argmax(masked)
    best_dq = masked[best_idx]
    best_comm = jnp.where(best_dq <= NEG_INF / 2, self_comm, comm[best_idx])
    best_comm_ref[0] = best_comm.astype(jnp.int32)
    best_dq_ref[0] = best_dq.astype(jnp.float32)


@partial(jax.jit, static_argnames=("interpret",))
def louvain_scan(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr, sigma_self,
                 params, interpret=True):
    """Run the community-scan Pallas kernel over a (TV, MD) tile.

    ``params`` is f32[1, 2] = [[m, pick_less_flag]].  Returns
    (best_comm i32[TV], best_dq f32[TV]).
    """
    tv, md = nbr_comm.shape
    grid = (tv,)
    row2 = pl.BlockSpec((1, md), lambda v: (v, 0))
    row1 = pl.BlockSpec((1,), lambda v: (v,))
    scalar = pl.BlockSpec((1, 2), lambda v: (0, 0))
    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[row2, row2, row1, row1, row2, row1, scalar],
        out_specs=[row1, row1],
        out_shape=[
            jax.ShapeDtypeStruct((tv,), jnp.int32),
            jax.ShapeDtypeStruct((tv,), jnp.float32),
        ],
        interpret=interpret,
    )(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr, sigma_self, params)


def pack_params(m, pick_less):
    """Host helper: pack (m, pick_less) into the kernel's params array."""
    return jnp.asarray([[float(m), 1.0 if pick_less else 0.0]], jnp.float32)
