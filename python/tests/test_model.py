"""L2 model semantics: move_step acceptance logic + modularity evaluator."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import PAD, modularity_ref
from tests.test_kernel import random_tile


def test_move_step_accepts_only_positive_dq():
    tile = random_tile(64, 32, ncomm=8)
    params = model.pack_params(64.0, False)
    out_comm, dq, accept, dq_total = model.move_step(*tile, params)
    out_comm, dq, accept = map(np.asarray, (out_comm, dq, accept))
    self_comm = tile[2]
    moved = out_comm != self_comm
    assert np.array_equal(moved, np.asarray(accept, bool))
    assert np.all(dq[moved] > 0)
    np.testing.assert_allclose(
        float(np.asarray(dq_total)[0]), dq[moved].sum(), rtol=1e-4)


def test_move_step_rejects_keeps_membership():
    # A tile where every vertex is best off staying: singleton communities
    # with huge Sigma penalty for any move.
    tv, md = 16, 32
    nbr_comm = np.full((tv, md), PAD, np.int32)
    nbr_wt = np.zeros((tv, md), np.float32)
    nbr_comm[:, 0] = 1
    nbr_wt[:, 0] = 0.001
    self_comm = np.zeros(tv, np.int32)
    ktot = np.full(tv, 10.0, np.float32)
    sigma_nbr = np.full((tv, md), 1e6, np.float32)  # huge target community
    sigma_self = np.zeros(tv, np.float32)
    params = model.pack_params(100.0, False)
    out_comm, _, accept, dq_total = model.move_step(
        nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr, sigma_self, params)
    np.testing.assert_array_equal(np.asarray(out_comm), self_comm)
    assert np.asarray(accept).sum() == 0
    assert float(np.asarray(dq_total)[0]) == 0.0


def test_move_step_pick_less_respected():
    tile = random_tile(128, 32, ncomm=32)
    params = model.pack_params(64.0, True)
    out_comm, _, accept, _ = model.move_step(*tile, params)
    out_comm = np.asarray(out_comm)
    self_comm = tile[2]
    moved = out_comm != self_comm
    assert np.all(out_comm[moved] < self_comm[moved])


def test_modularity_chunk_matches_ref():
    rng = np.random.default_rng(7)
    c, m = 256, 500.0
    sigma = rng.uniform(0, 50, c).astype(np.float32)
    big = (sigma + rng.uniform(0, 50, c)).astype(np.float32)
    minv = np.asarray([1.0 / (2 * m)], np.float32)
    got = float(np.asarray(model.modularity_chunk(sigma, big, minv))[0])
    want = modularity_ref(sigma, big, m)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_modularity_zero_padding_is_noop():
    sigma = np.zeros(64, np.float32)
    big = np.zeros(64, np.float32)
    sigma[:4] = [4, 3, 2, 1]
    big[:4] = [8, 6, 4, 2]
    minv = np.asarray([1.0 / 40.0], np.float32)
    full = float(np.asarray(model.modularity_chunk(sigma, big, minv))[0])
    short = float(np.asarray(
        model.modularity_chunk(sigma[:4], big[:4], minv))[0])
    np.testing.assert_allclose(full, short, rtol=1e-6)


def test_modularity_perfect_partition_bounds():
    # One community holding all edges: Q = 1/2 - 1/4 = 0.25 for
    # sigma = m, Sigma = 2m... sanity of sign and range.
    m = 100.0
    sigma = np.asarray([m], np.float32)         # all weight internal
    big = np.asarray([2 * m], np.float32)
    minv = np.asarray([1.0 / (2 * m)], np.float32)
    q = float(np.asarray(model.modularity_chunk(sigma, big, minv))[0])
    assert -0.5 <= q <= 1.0
    np.testing.assert_allclose(q, 0.5 - 1.0, rtol=1e-6)  # single community


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), c=st.integers(1, 512),
       m=st.floats(1.0, 1e4))
def test_modularity_chunk_hypothesis(seed, c, m):
    rng = np.random.default_rng(seed)
    sigma = rng.uniform(0, m, c).astype(np.float32)
    big = (sigma + rng.uniform(0, m, c)).astype(np.float32)
    minv = np.asarray([1.0 / (2 * m)], np.float32)
    got = float(np.asarray(model.modularity_chunk(sigma, big, minv))[0])
    want = modularity_ref(sigma, big, m)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_move_step_dq_total_consistent(seed):
    rng = np.random.default_rng(seed)
    tile = random_tile(32, 16, ncomm=6, rng=rng, weights="random")
    params = model.pack_params(32.0, False)
    _, dq, accept, dq_total = model.move_step(*tile, params)
    dq, accept = np.asarray(dq), np.asarray(accept, bool)
    np.testing.assert_allclose(float(np.asarray(dq_total)[0]),
                               dq[accept].sum(), rtol=1e-4, atol=1e-6)
