"""AOT pipeline: HLO text generation + manifest consistency."""

from __future__ import annotations

import os

import pytest

from compile import aot
from compile.kernels.louvain_scan import TILE_CLASSES


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    rows = aot.build_all(out)
    aot.write_manifest(out, rows)
    return out, rows


def test_builds_all_tile_classes(built):
    out, rows = built
    kinds = [r[1] for r in rows]
    assert kinds.count("move_step") == len(TILE_CLASSES)
    assert kinds.count("modularity") == 1
    for name, _, _ in rows:
        assert os.path.exists(os.path.join(out, name))


def test_hlo_text_is_parseable_text(built):
    out, rows = built
    for name, _, _ in rows:
        text = open(os.path.join(out, name)).read()
        # HLO text modules start with "HloModule" and declare ENTRY.
        assert text.lstrip().startswith("HloModule"), name
        assert "ENTRY" in text, name
        # Tuple return (the Rust loader unwraps tuples).
        assert "tuple" in text, name


def test_move_step_hlo_has_expected_shapes(built):
    out, rows = built
    for name, kind, params in rows:
        if kind != "move_step":
            continue
        d = dict(p.split("=") for p in params.split())
        tv, md = int(d["tv"]), int(d["md"])
        text = open(os.path.join(out, name)).read()
        assert f"s32[{tv},{md}]" in text  # nbr_comm input
        assert f"f32[{tv},{md}]" in text  # nbr_wt input
        assert f"s32[{tv}]" in text       # best_comm output


def test_manifest_round_trips(built):
    out, rows = built
    lines = open(os.path.join(out, "manifest.txt")).read().splitlines()
    assert len(lines) == len(rows)
    for line, row in zip(lines, rows):
        name, kind, params = line.split("\t")
        assert (name, kind, params) == row


def test_no_mosaic_custom_calls(built):
    # interpret=True must lower pallas to plain HLO; a Mosaic custom-call
    # would be unloadable by the CPU PJRT client.
    out, rows = built
    for name, _, _ in rows:
        text = open(os.path.join(out, name)).read()
        assert "tpu_custom_call" not in text, name
        assert "mosaic" not in text.lower(), name
