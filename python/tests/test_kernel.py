"""Kernel vs reference: the CORE correctness signal of the L1 layer.

The Pallas kernel (interpret=True) must agree with BOTH references:
the vectorized jnp oracle and the scalar hashtable-style loop.
Hypothesis sweeps shapes, degrees, community layouts and the pick-less
flag; fixed edge cases pin the padding / tie / no-candidate semantics.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.louvain_scan import TILE_CLASSES, louvain_scan, pack_params
from compile.kernels.ref import (NEG_INF, PAD, scan_tile_ref,
                                 scan_tile_ref_loop)

RNG = np.random.default_rng(42)


def random_tile(tv, md, ncomm, m=64.0, density=0.7, rng=RNG,
                weights="uniform"):
    """Random tile with PAD-terminated rows and community-consistent sigma."""
    deg = rng.integers(0, int(md * density) + 1, size=tv)
    nbr_comm = np.full((tv, md), PAD, np.int32)
    nbr_wt = np.zeros((tv, md), np.float32)
    sigma = (rng.uniform(1.0, 2 * m, size=ncomm)).astype(np.float32)
    sigma_nbr = np.zeros((tv, md), np.float32)
    for v in range(tv):
        d = int(deg[v])
        cs = rng.integers(0, ncomm, size=d).astype(np.int32)
        nbr_comm[v, :d] = cs
        if weights == "uniform":
            nbr_wt[v, :d] = 1.0
        else:
            nbr_wt[v, :d] = rng.uniform(0.25, 4.0, size=d).astype(np.float32)
        sigma_nbr[v, :d] = sigma[cs]
    self_comm = rng.integers(0, ncomm, size=tv).astype(np.int32)
    ktot = nbr_wt.sum(axis=1).astype(np.float32)
    sigma_self = sigma[self_comm]
    return (nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr, sigma_self)


def run_all(tile, m, pick_less):
    nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr, sigma_self = tile
    params = pack_params(m, pick_less)
    kc, kq = louvain_scan(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr,
                          sigma_self, params)
    rc, rq = scan_tile_ref(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr,
                           sigma_self, m, pick_less)
    return (np.asarray(kc), np.asarray(kq)), (np.asarray(rc), np.asarray(rq))


def assert_matches(k, r, tile, m, pick_less, loop_check=False):
    (kc, kq), (rc, rq) = k, r
    np.testing.assert_allclose(kq, rq, rtol=2e-5, atol=2e-6)
    np.testing.assert_array_equal(kc, rc)
    if loop_check:
        lc, lq = scan_tile_ref_loop(*tile, m, pick_less)
        # dq values must match; community choice may differ only on exact
        # f32 ties, which the constructions here avoid.
        np.testing.assert_allclose(kq, lq, rtol=2e-4, atol=2e-5)
        np.testing.assert_array_equal(kc, lc)


@pytest.mark.parametrize("tv,md", TILE_CLASSES)
@pytest.mark.parametrize("pick_less", [False, True])
def test_kernel_matches_ref_all_classes(tv, md, pick_less):
    tile = random_tile(tv, md, ncomm=max(4, tv // 4))
    k, r = run_all(tile, 64.0, pick_less)
    assert_matches(k, r, tile, 64.0, pick_less, loop_check=(md <= 128))


@pytest.mark.parametrize("weights", ["uniform", "random"])
def test_kernel_weighted_edges(weights):
    tile = random_tile(64, 32, ncomm=8, weights=weights)
    k, r = run_all(tile, 32.0, False)
    assert_matches(k, r, tile, 32.0, False, loop_check=True)


def test_all_padding_rows_stay_put():
    tv, md = 16, 32
    nbr_comm = np.full((tv, md), PAD, np.int32)
    nbr_wt = np.zeros((tv, md), np.float32)
    self_comm = np.arange(tv, dtype=np.int32)
    ktot = np.zeros(tv, np.float32)
    sigma = np.zeros((tv, md), np.float32)
    sigma_self = np.zeros(tv, np.float32)
    params = pack_params(10.0, False)
    kc, kq = louvain_scan(nbr_comm, nbr_wt, self_comm, ktot, sigma, sigma_self,
                          params)
    np.testing.assert_array_equal(np.asarray(kc), self_comm)
    assert np.all(np.asarray(kq) <= NEG_INF / 2)


def test_all_neighbours_in_own_community_stay_put():
    tv, md = 8, 32
    nbr_comm = np.zeros((tv, md), np.int32)  # everyone in community 0
    nbr_wt = np.ones((tv, md), np.float32)
    self_comm = np.zeros(tv, np.int32)
    ktot = nbr_wt.sum(axis=1)
    sigma = np.full((tv, md), 40.0, np.float32)
    sigma_self = np.full(tv, 40.0, np.float32)
    kc, kq = louvain_scan(nbr_comm, nbr_wt, self_comm, ktot, sigma, sigma_self,
                          pack_params(100.0, False))
    np.testing.assert_array_equal(np.asarray(kc), self_comm)
    assert np.all(np.asarray(kq) <= NEG_INF / 2)


def test_pick_less_only_moves_down():
    for seed in range(5):
        rng = np.random.default_rng(seed)
        tile = random_tile(64, 32, ncomm=16, rng=rng)
        (kc, kq), _ = run_all(tile, 48.0, True)
        self_comm = tile[2]
        moved = kc != self_comm
        assert np.all(kc[moved] < self_comm[moved])


def test_pick_less_false_allows_up_moves():
    # Construct a vertex whose only improving move is to a *larger* id.
    tv, md = 4, 32
    nbr_comm = np.full((tv, md), PAD, np.int32)
    nbr_wt = np.zeros((tv, md), np.float32)
    nbr_comm[:, :4] = 7  # strong pull to community 7
    nbr_wt[:, :4] = 2.0
    self_comm = np.zeros(tv, np.int32)
    ktot = nbr_wt.sum(axis=1)
    sigma_nbr = np.where(nbr_comm == 7, 4.0, 0.0).astype(np.float32)
    sigma_self = np.full(tv, 1.0, np.float32)
    m = 50.0
    kc, kq = louvain_scan(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr,
                          sigma_self, pack_params(m, False))
    assert np.all(np.asarray(kc) == 7)
    assert np.all(np.asarray(kq) > 0)
    kc2, _ = louvain_scan(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr,
                          sigma_self, pack_params(m, True))
    np.testing.assert_array_equal(np.asarray(kc2), self_comm)  # blocked


def test_self_community_excluded_from_candidates():
    tv, md = 4, 32
    nbr_comm = np.full((tv, md), PAD, np.int32)
    nbr_wt = np.zeros((tv, md), np.float32)
    nbr_comm[:, :8] = 3
    nbr_wt[:, :8] = 1.0
    self_comm = np.full(tv, 3, np.int32)  # already in community 3
    ktot = nbr_wt.sum(axis=1)
    sigma_nbr = np.full((tv, md), 16.0, np.float32)
    sigma_self = np.full(tv, 16.0, np.float32)
    kc, kq = louvain_scan(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr,
                          sigma_self, pack_params(20.0, False))
    np.testing.assert_array_equal(np.asarray(kc), self_comm)


def test_tie_break_first_slot():
    # Two equally-good candidate communities; argmax must take the first.
    tv, md = 1, 32
    nbr_comm = np.full((tv, md), PAD, np.int32)
    nbr_wt = np.zeros((tv, md), np.float32)
    nbr_comm[0, 0], nbr_comm[0, 1] = 5, 9
    nbr_wt[0, 0] = nbr_wt[0, 1] = 1.0
    self_comm = np.zeros(tv, np.int32)
    ktot = nbr_wt.sum(axis=1)
    sigma_nbr = np.full((tv, md), 3.0, np.float32)
    sigma_self = np.zeros(tv, np.float32)
    kc, _ = louvain_scan(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr,
                         sigma_self, pack_params(10.0, False))
    assert int(kc[0]) == 5


def test_duplicate_community_slots_accumulate():
    # K_{i->c} must sum across *all* slots of community c (the dense
    # hashtable semantics), not just the argmax slot.
    tv, md = 1, 32
    nbr_comm = np.full((tv, md), PAD, np.int32)
    nbr_wt = np.zeros((tv, md), np.float32)
    nbr_comm[0, :3] = 2          # community 2 via three slots, total w=3
    nbr_wt[0, :3] = 1.0
    nbr_comm[0, 3] = 4           # community 4 via one slot, w=2
    nbr_wt[0, 3] = 2.0
    self_comm = np.zeros(tv, np.int32)
    ktot = nbr_wt.sum(axis=1)
    sigma_nbr = np.full((tv, md), 1.0, np.float32)
    sigma_self = np.zeros(tv, np.float32)
    kc, kq = louvain_scan(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr,
                          sigma_self, pack_params(10.0, False))
    assert int(kc[0]) == 2  # 3.0 accumulated beats 2.0
    lc, lq = scan_tile_ref_loop(nbr_comm, nbr_wt, self_comm, ktot, sigma_nbr,
                                sigma_self, 10.0, False)
    np.testing.assert_allclose(np.asarray(kq), lq, rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    tv=st.integers(1, 24),
    md=st.sampled_from([8, 16, 32, 64]),
    ncomm=st.integers(1, 12),
    pick_less=st.booleans(),
    m=st.floats(4.0, 512.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(tv, md, ncomm, pick_less, m, seed):
    rng = np.random.default_rng(seed)
    tile = random_tile(tv, md, ncomm, m=m, rng=rng, weights="random")
    k, r = run_all(tile, m, pick_less)
    assert_matches(k, r, tile, m, pick_less, loop_check=True)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), density=st.floats(0.0, 1.0))
def test_kernel_density_sweep(seed, density):
    rng = np.random.default_rng(seed)
    tile = random_tile(32, 32, 8, density=density, rng=rng)
    k, r = run_all(tile, 64.0, False)
    assert_matches(k, r, tile, 64.0, False)
