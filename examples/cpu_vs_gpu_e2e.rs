//! End-to-end driver: the paper's full pipeline on a real (small)
//! workload, proving all layers compose.
//!
//! 1. Builds the 13-graph Table 2 mirror suite.
//! 2. Runs all seven systems (GVE-Louvain, ν-Louvain on the GPU
//!    simulator, Vite, Grappolo, NetworKit, cuGraph, Nido).
//! 3. Runs the REAL three-layer path — Pallas kernel → HLO artifact →
//!    PJRT from Rust — for ν-Louvain's local-moving phase, and
//!    cross-checks its modularity (host vs device reduction).
//! 4. Reports the paper's headline numbers: edges/s for GVE-Louvain,
//!    the ν/GVE speedup (paper: ≈1.03×), and mean speedups vs the five
//!    baselines (paper Table 1).
//!
//! ```bash
//! make artifacts && cargo run --release --example cpu_vs_gpu_e2e
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use gve_louvain::baselines::{run_system, System};
use gve_louvain::coordinator::metrics::{edges_per_sec, fmt_ns, geomean};
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::runner::{compare_on_entry, mean_speedup, ComparisonCell};
use gve_louvain::coordinator::suite::SUITE;
use gve_louvain::gpusim::nulouvain::NuParams;
use gve_louvain::runtime::executor::MoveExecutor;
use gve_louvain::runtime::pjrt_louvain::PjrtLouvain;

fn main() -> anyhow::Result<()> {
    let offset: i32 = std::env::var("GVE_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(-3);
    let systems = [
        System::GveLouvain,
        System::NuLouvain,
        System::Vite,
        System::Grappolo,
        System::NetworKit,
        System::CuGraph,
        System::Nido,
    ];

    // --- Full-suite comparison.
    println!("=== e2e: running {} systems x {} graphs (offset {offset}) ===\n", systems.len(), SUITE.len());
    let mut cells: Vec<ComparisonCell> = Vec::new();
    let mut t = Table::new(
        "Cross-system results (Fig 11/12/13 rows)",
        &["graph", "system", "modeled", "Q", "|Γ|"],
    );
    for entry in &SUITE {
        for c in compare_on_entry(entry, offset, &systems, 1, 1, 42) {
            t.row(vec![
                c.graph.into(),
                c.system.name().into(),
                c.modeled_ns.map(|x| fmt_ns(x as u64)).unwrap_or_else(|| "OOM".into()),
                format!("{:.4}", c.modularity),
                format!("{}", c.num_communities),
            ]);
            cells.push(c);
        }
    }
    print!("{}", t.render());

    // --- Headline: GVE-Louvain processing rate (paper: 560 M edges/s
    // on a 3.8B-edge graph with 64 threads; here: 1 core, small suite).
    let mut rates = Vec::new();
    for entry in &SUITE {
        let g = entry.graph(offset, 42);
        let out = run_system(System::GveLouvain, &g, 1, 42);
        rates.push(edges_per_sec(g.num_edges(), out.wall_ns));
    }
    println!("\nGVE-Louvain geomean rate: {:.2}M edges/s (1 core, this host)", geomean(&rates) / 1e6);

    // --- Headline: speedups (paper Table 1 shape).
    println!("\nMean modeled speedup of GVE-Louvain (paper Table 1 shape):");
    for (other, paper) in [
        (System::Vite, "50x"),
        (System::Grappolo, "22x"),
        (System::NetworKit, "20x"),
        (System::Nido, "56x"),
        (System::CuGraph, "5.8x"),
        (System::NuLouvain, "~1x (the headline)"),
    ] {
        match mean_speedup(&cells, System::GveLouvain, other) {
            Some(s) => println!("  vs {:<12} {s:>7.1}x   (paper: {paper})", other.name()),
            None => println!("  vs {:<12}      —   (OOM on all graphs)", other.name()),
        }
    }

    // --- The real three-layer path on one representative graph.
    println!("\n=== three-layer PJRT path (Pallas→HLO→PJRT→Rust) ===");
    let exec = MoveExecutor::discover()?;
    println!("platform {} | tile classes {:?}", exec.platform(), exec.classes());
    let entry = &SUITE[0]; // indochina-2004 stand-in
    let g = entry.graph(offset, 42);
    let out = PjrtLouvain::new(&exec, NuParams::default()).run(&g)?;
    let host_q = out.modularity;
    let dev_q = out.modularity_device.expect("device modularity");
    println!(
        "{}: Q={host_q:.4} device-Q={dev_q:.4} |Γ|={} passes={} dispatches={} wall={}",
        entry.name,
        out.num_communities,
        out.passes,
        out.dispatches,
        fmt_ns(out.wall_ns)
    );
    assert!((host_q - dev_q).abs() < 1e-3, "host/device modularity must agree");
    assert!(host_q > 0.5, "three-layer path must find real communities");

    println!("\ne2e OK");
    Ok(())
}
