//! Quickstart: generate a graph, run GVE-Louvain, inspect the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gve_louvain::coordinator::metrics::{edges_per_sec, fmt_ns};
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::louvain::{gve::GveLouvain, params::LouvainParams};

fn main() {
    // 1. A web-family graph (power-law degrees, strong communities) with
    //    2^13 = 8192 vertices.
    let g = generate(GraphFamily::Web, 13, 42);
    println!(
        "graph: {} vertices, {} directed edge slots, avg degree {:.1}",
        g.num_vertices(),
        g.num_edges(),
        g.num_edges() as f64 / g.num_vertices() as f64
    );

    // 2. GVE-Louvain with the paper's adopted configuration (§4.1):
    //    dynamic schedule, 20-iteration cap, tolerance 0.01 with drop
    //    rate 10, aggregation tolerance 0.8, pruning, Far-KV tables.
    let out = GveLouvain::new(LouvainParams::default()).run(&g);

    println!("modularity Q      = {:.4}", out.modularity);
    println!("communities |Γ|   = {}", out.num_communities);
    println!("passes            = {}", out.passes);
    println!("runtime           = {}", fmt_ns(out.total_ns));
    println!("rate              = {:.1}M edges/s", edges_per_sec(g.num_edges(), out.total_ns) / 1e6);

    // 3. Phase split (the paper's Fig 14: local-moving should dominate
    //    on web graphs).
    let (mv, ag, other) = out.phase_split();
    println!("phase split       = {:.0}% move / {:.0}% aggregate / {:.0}% other",
             100.0 * mv, 100.0 * ag, 100.0 * other);
    for (i, p) in out.pass_stats.iter().enumerate() {
        println!(
            "  pass {i}: |V'|={:<6} iterations={} communities={} dq={:.4}",
            p.vertices, p.iterations, p.communities, p.dq
        );
    }

    assert!(out.modularity > 0.8, "web-family graphs should score high");
    println!("OK");
}
