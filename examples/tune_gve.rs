//! Tuning explorer: reproduce the *direction* of every §4.1 ablation on
//! a chosen graph — the interactive companion to `bench fig2_optimizations`.
//!
//! ```bash
//! cargo run --release --example tune_gve [-- --family web --scale 12]
//! ```

use gve_louvain::coordinator::metrics::fmt_ns;
use gve_louvain::coordinator::report::Table;
use gve_louvain::graph::generators::{generate, GraphFamily};
use gve_louvain::louvain::params::{AggregationKind, TableKind};
use gve_louvain::louvain::{gve::GveLouvain, LouvainParams};
use gve_louvain::parallel::schedule::Schedule;

fn arg(key: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() {
    let family = GraphFamily::parse(&arg("--family", "web")).expect("bad family");
    let scale: u32 = arg("--scale", "12").parse().expect("bad scale");
    let g = generate(family, scale, 42);
    println!("tuning on {}-s{scale}: {} vertices, {} edges\n", family.name(), g.num_vertices(), g.num_edges());

    let base = LouvainParams::default();
    let variants: Vec<(&str, LouvainParams)> = vec![
        ("adopted (dynamic/20/0.01/drop10/τagg0.8/prune/FarKV/CSR)", base.clone()),
        ("schedule=static", LouvainParams { schedule: Schedule::Static, ..base.clone() }),
        ("schedule=guided", LouvainParams { schedule: Schedule::Guided, ..base.clone() }),
        ("schedule=auto", LouvainParams { schedule: Schedule::Auto, ..base.clone() }),
        ("max-iterations=100", LouvainParams { max_iterations: 100, ..base.clone() }),
        ("tolerance-drop=1 (no scaling)", LouvainParams { tolerance_drop: 1.0, ..base.clone() }),
        ("initial-tolerance=1e-6", LouvainParams { tolerance: 1e-6, ..base.clone() }),
        ("aggregation-tolerance=1 (off)", LouvainParams { aggregation_tolerance: 1.0, ..base.clone() }),
        ("pruning=off", LouvainParams { pruning: false, ..base.clone() }),
        ("table=map", LouvainParams { table: TableKind::Map, ..base.clone() }),
        ("table=close-kv", LouvainParams { table: TableKind::CloseKv, ..base.clone() }),
        ("aggregation=2d-arrays", LouvainParams { aggregation: AggregationKind::TwoDim, ..base.clone() }),
    ];

    let mut table = Table::new("GVE-Louvain ablations (Fig 2 direction check)", &["variant", "time", "rel", "Q", "passes"]);
    let mut base_ns = 0u64;
    for (name, params) in variants {
        // Median of 3 runs.
        let mut times: Vec<u64> = (0..3)
            .map(|_| {
                let t0 = std::time::Instant::now();
                let _ = GveLouvain::new(params.clone()).run(&g);
                t0.elapsed().as_nanos() as u64
            })
            .collect();
        times.sort_unstable();
        let med = times[1];
        let out = GveLouvain::new(params).run(&g);
        if base_ns == 0 {
            base_ns = med;
        }
        table.row(vec![
            name.into(),
            fmt_ns(med),
            format!("{:.2}", med as f64 / base_ns as f64),
            format!("{:.4}", out.modularity),
            format!("{}", out.passes),
        ]);
    }
    print!("{}", table.render());
    println!("\nrel > 1.0 means the variant is slower than the adopted config;");
    println!("the paper's Fig 2 directions: map/2d/close-kv/no-pruning slower,");
    println!("strict tolerances slower, schedules roughly comparable (1 core).");
}
