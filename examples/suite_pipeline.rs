//! Suite pipeline: generate the Table 2 mirror suite, persist it in the
//! binary format (the "Vite conversion" step), reload, and profile
//! GVE-Louvain per dataset family — the paper's Fig 14/15 views.
//!
//! ```bash
//! cargo run --release --example suite_pipeline [-- --offset -3]
//! ```

use gve_louvain::coordinator::metrics::{edges_per_sec, fmt_ns};
use gve_louvain::coordinator::report::Table;
use gve_louvain::coordinator::suite::SUITE;
use gve_louvain::graph::io;
use gve_louvain::louvain::{gve::GveLouvain, params::LouvainParams};

fn main() -> anyhow::Result<()> {
    let offset: i32 = std::env::args()
        .skip_while(|a| a != "--offset")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(-3);
    let dir = std::env::temp_dir().join("gve_suite");
    std::fs::create_dir_all(&dir)?;

    let mut t = Table::new(
        &format!("Suite pipeline (scale offset {offset})"),
        &["graph", "family", "|V|", "|E|", "Q", "|Γ|", "passes", "time", "ME/s", "move%", "agg%", "pass1%"],
    );

    for entry in &SUITE {
        // Generate → persist → reload (exercises the IO path end-to-end).
        let g = entry.graph(offset, 42);
        let path = dir.join(format!("{}.bin", entry.name));
        io::write_binary(&g, &path)?;
        let g = io::read_binary(&path)?;

        let out = GveLouvain::new(LouvainParams::default()).run(&g);
        let (mv, ag, _) = out.phase_split();
        t.row(vec![
            entry.name.into(),
            entry.family.name().into(),
            format!("{}", g.num_vertices()),
            format!("{}", g.num_edges()),
            format!("{:.4}", out.modularity),
            format!("{}", out.num_communities),
            format!("{}", out.passes),
            fmt_ns(out.total_ns),
            format!("{:.2}", edges_per_sec(g.num_edges(), out.total_ns) / 1e6),
            format!("{:.0}%", mv * 100.0),
            format!("{:.0}%", ag * 100.0),
            format!("{:.0}%", out.first_pass_fraction() * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!("\n(The paper's shapes to look for: web graphs dominated by the");
    println!(" local-moving phase and the first pass; road/k-mer graphs spend");
    println!(" more time in later passes; social graphs aggregation-heavy.)");
    Ok(())
}
